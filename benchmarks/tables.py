"""One benchmark per paper table (deliverable (d)).

Training-based tables run scaled-down (smoke-config, synthetic C4) on CPU;
memory tables use the exact Appendix-F estimator at the paper's full sizes
and check against the paper's published numbers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig, ParamConfig
from repro.core import memory as memory_lib
from repro.core import sltrain, support
from repro.data.pipeline import SyntheticC4
from repro.models import registry
from repro.optim import optimizers
from repro.train import step as step_lib

Row = Dict[str, object]


def _smoke_cfg(mode: str = "sltrain", **kw):
    cfg = registry.get_smoke_config("llama_60m")
    return dataclasses.replace(
        cfg, param=dataclasses.replace(cfg.param, mode=mode, **kw))


def _train(cfg, steps: int, *, seed: int = 0, batch: int = 8, seq: int = 64,
           lr: float = 3e-3, params=None, trainable=None) -> Dict:
    """Train ``steps`` on synthetic C4; returns final params + eval loss.
    ``trainable``: optional predicate(path)->bool freezing other leaves."""
    api = registry.get_api(cfg)
    if params is None:
        params, consts = api.init(cfg, jax.random.PRNGKey(seed), seed=seed)
    else:
        params, consts = params
    oc = OptimizerConfig(lr=lr, warmup_steps=max(1, steps // 10),
                         total_steps=steps)
    opt = optimizers.make(oc)
    opt_state = opt.init(params)
    tstep = jax.jit(step_lib.make_train_step(cfg, api, opt))
    data = SyntheticC4(cfg.vocab_size, seq, batch, seed=42)
    t0 = time.perf_counter()
    loss = float("nan")
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, metrics = tstep(params, opt_state, consts, b)
    jax.block_until_ready(metrics["loss"])
    dt = time.perf_counter() - t0
    # eval on 4 fresh batches
    ev = jax.jit(step_lib.make_eval_step(cfg, api))
    losses = []
    for _ in range(4):
        b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        losses.append(float(ev(params, consts, b)["ce"]))
    ce = float(np.mean(losses))
    return {"params": params, "consts": consts, "ce": ce,
            "ppl": float(np.exp(ce)), "s_per_step": dt / steps,
            "tokens_per_s": batch * seq * steps / dt}


# ---------------------------------------------------------------------------
# Table 2 / Table 8: parameter + memory estimates at the paper's full sizes
# ---------------------------------------------------------------------------

# Paper Table 2 published values (PPL, Param M, Mem G) for cross-checking.
PAPER_TABLE2 = {
    "60m": {"full": (34.06, 58, 0.35), "lowrank": (78.18, 43, 0.24),
            "relora": (37.04, 58, 0.36), "galore": (34.88, 58, 0.28),
            "sltrain": (34.15, 44, 0.26)},
    "130m": {"full": (24.36, 134, 0.81), "lowrank": (45.51, 94, 0.57),
             "relora": (29.37, 134, 0.84), "galore": (25.36, 134, 0.61),
             "sltrain": (26.04, 97, 0.60)},
    "350m": {"full": (18.80, 368, 2.21), "lowrank": (37.41, 185, 1.11),
             "relora": (29.08, 368, 1.85), "galore": (18.95, 368, 1.59),
             "sltrain": (19.42, 194, 1.24)},
    "1b": {"full": (15.56, 1339, 8.04), "lowrank": (142.5, 609, 3.66),
           "relora": (18.33, 1339, 6.34), "galore": (15.64, 1339, 4.76),
           "sltrain": (16.14, 646, 4.16)},
}


def table2_memory() -> List[Row]:
    rows = []
    for size in ("60m", "130m", "350m", "1b", "7b"):
        delta = 0.05 if size == "7b" else 0.03
        est = memory_lib.paper_table8(size, delta=delta)
        for method, d in est.items():
            ref = PAPER_TABLE2.get(size, {}).get(method)
            rows.append({
                "bench": "table2_memory", "size": size, "method": method,
                "params_M": round(d["params_M"], 1),
                "total_G": round(d["total_G"], 2),
                "paper_params_M": ref[1] if ref else "",
                "paper_total_G": ref[2] if ref else "",
            })
        # TPU adaptation (DESIGN §3): int32 indices instead of the paper's
        # int64 convention — sltrain index memory halves
        cfg = dict(memory_lib.PAPER_LLAMA[size])
        rank = cfg.pop("rank")
        inv = memory_lib.llama_inventory(**cfg)
        d32 = memory_lib.estimate(inv, "sltrain", rank=rank, delta=delta,
                                  index_bytes=4).as_dict()
        rows.append({
            "bench": "table2_memory", "size": size,
            "method": "sltrain_int32idx",
            "params_M": round(d32["params_M"], 1),
            "total_G": round(d32["total_G"], 2),
            "paper_params_M": "", "paper_total_G": "",
        })
    return rows


# ---------------------------------------------------------------------------
# Table 1: random vs top sparse support (scaled down)
# ---------------------------------------------------------------------------

def table1_support(steps: int = 200) -> List[Row]:
    """Scaled-down Table 1: pretrain a dense smoke model, replace weights by
    rank-r approx, then compare pruning vs training the sparse residual on
    top/random support."""
    cfg_d = _smoke_cfg("dense")
    full = _train(cfg_d, steps)
    api = registry.get_api(cfg_d)
    rows = [{"bench": "table1_support", "variant": "full_rank",
             "ppl": round(full["ppl"], 2)}]

    r, delta = 8, 0.05

    def lowrank_residual(w):
        wf = np.asarray(w, np.float32)
        u, s, vt = np.linalg.svd(wf, full_matrices=False)
        L0 = (u[:, :r] * s[:r]) @ vt[:r]
        return L0, wf - L0

    def rebuild(keep: str):
        """Return params with adapted linears replaced by L0 (+ sparse)."""
        new = jax.tree_util.tree_map_with_path(lambda p, x: x, full["params"])
        def visit(path, leaf):
            names = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
            if str(names[-1]) != "w" or "embed" in names or "lm_head" in names:
                return leaf
            arr = np.asarray(leaf, np.float32)
            stack = arr.reshape(-1, arr.shape[-2], arr.shape[-1])
            out = []
            for w in stack:
                L0, R = lowrank_residual(w)
                nnz = max(1, int(delta * R.size))
                if keep == "none":
                    W = L0
                elif keep == "top":
                    th = np.partition(np.abs(R).ravel(), -nnz)[-nnz]
                    W = L0 + R * (np.abs(R) >= th)
                else:  # random
                    mask = np.zeros(R.size, bool)
                    mask[np.random.default_rng(0).choice(R.size, nnz,
                                                         False)] = True
                    W = L0 + R * mask.reshape(R.shape)
                out.append(W)
            return jnp.asarray(np.stack(out).reshape(arr.shape), leaf.dtype)
        return jax.tree_util.tree_map_with_path(visit, new)

    ev = jax.jit(step_lib.make_eval_step(cfg_d, api))
    data = SyntheticC4(cfg_d.vocab_size, 64, 8, seed=42)
    def ppl_of(params):
        losses = []
        for _ in range(4):
            b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
            losses.append(float(ev(params, full["consts"], b)["ce"]))
        return float(np.exp(np.mean(losses)))

    for variant, keep in [("lowrank_L0", "none"), ("L0_top_prune", "top"),
                          ("L0_rand_prune", "random")]:
        rows.append({"bench": "table1_support", "variant": variant,
                     "ppl": round(ppl_of(rebuild(keep)), 2)})
    return rows


# ---------------------------------------------------------------------------
# Table 2-PPL / Fig 1 analogue: methods at equal token budget (scaled)
# ---------------------------------------------------------------------------

def table2_ppl(steps: int = 200) -> List[Row]:
    rows = []
    for mode in ("dense", "lowrank", "sltrain", "relora"):
        cfg = _smoke_cfg(mode)
        out = _train(cfg, steps)
        n_params = sum(x.size for x in jax.tree.leaves(
            registry.get_api(cfg).init(cfg, jax.random.PRNGKey(0))[0]))
        rows.append({"bench": "table2_ppl", "method": mode,
                     "ppl": round(out["ppl"], 2),
                     "params_K": round(n_params / 1e3, 1),
                     "tokens_per_s": int(out["tokens_per_s"])})
    # galore = dense params + galore optimizer
    cfg = _smoke_cfg("dense")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    oc = OptimizerConfig(name="galore_adamw", lr=3e-3, galore_rank=8,
                         warmup_steps=30, total_steps=steps)
    opt = optimizers.make(oc)
    st = opt.init(params)
    tstep = jax.jit(step_lib.make_train_step(cfg, api, opt))
    data = SyntheticC4(cfg.vocab_size, 64, 8, seed=42)
    t0 = time.perf_counter()
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, st, m = tstep(params, st, consts, b)
    dt = time.perf_counter() - t0
    ev = jax.jit(step_lib.make_eval_step(cfg, api))
    losses = [float(ev(params, consts,
                       {k: jnp.asarray(v) for k, v in
                        data.next_batch().items()})["ce"]) for _ in range(4)]
    rows.append({"bench": "table2_ppl", "method": "galore",
                 "ppl": round(float(np.exp(np.mean(losses))), 2),
                 "params_K": round(sum(x.size for x in
                                       jax.tree.leaves(params)) / 1e3, 1),
                 "tokens_per_s": int(8 * 64 * steps / dt)})
    return rows


# ---------------------------------------------------------------------------
# Table 3: training throughput (CPU tokens/s, relative)
# ---------------------------------------------------------------------------

def table3_throughput(steps: int = 30) -> List[Row]:
    rows = []
    for mode in ("dense", "sltrain", "lowrank"):
        cfg = _smoke_cfg(mode)
        out = _train(cfg, steps)
        rows.append({"bench": "table3_throughput", "method": mode,
                     "us_per_step": int(out["s_per_step"] * 1e6),
                     "tokens_per_s": int(out["tokens_per_s"])})
    return rows


# ---------------------------------------------------------------------------
# Table 5: inference memory + throughput, dense vs SLTrain(+sparse decode)
# ---------------------------------------------------------------------------

def table5_inference(new_tokens: int = 32) -> List[Row]:
    from repro.serve.engine import ServeEngine
    rows = []
    for mode, sparse in (("dense", False), ("sltrain", False),
                         ("sltrain", True)):
        cfg = _smoke_cfg(mode)
        api = registry.get_api(cfg)
        params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
        pbytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(params))
        ibytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(consts))
        eng = ServeEngine(cfg, params, consts, n_slots=4, max_len=64,
                          sparse_decode=sparse)
        for i in range(4):
            eng.submit([3 + i, 4, 5], max_new_tokens=new_tokens)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        rows.append({"bench": "table5_inference",
                     "method": mode + ("_sparse" if sparse else ""),
                     "param_MB": round((pbytes + ibytes) / 1e6, 2),
                     "tok_per_s": int(4 * new_tokens / dt)})
    return rows


# ---------------------------------------------------------------------------
# Table 6/7: rank r and sparsity δ ablation
# ---------------------------------------------------------------------------

def table6_ablation(steps: int = 120) -> List[Row]:
    rows = []
    for r, delta in ((4, 0.05), (8, 0.01), (8, 0.05), (8, 0.10), (16, 0.05)):
        cfg = _smoke_cfg("sltrain", rank=r, delta=delta)
        out = _train(cfg, steps)
        n_params = sum(x.size for x in jax.tree.leaves(
            registry.get_api(cfg).init(cfg, jax.random.PRNGKey(0))[0]))
        rows.append({"bench": "table6_ablation", "r": r, "delta": delta,
                     "ppl": round(out["ppl"], 2),
                     "params_K": round(n_params / 1e3, 1)})
    return rows


# ---------------------------------------------------------------------------
# Fig 4: varying the random support seed
# ---------------------------------------------------------------------------

def fig4_support_seeds(steps: int = 120, n_seeds: int = 3) -> List[Row]:
    rows = []
    for s in range(n_seeds):
        cfg = _smoke_cfg("sltrain")
        out = _train(cfg, steps, seed=s)
        rows.append({"bench": "fig4_support_seeds", "seed": s,
                     "ppl": round(out["ppl"], 2)})
    ppls = [r["ppl"] for r in rows]
    rows.append({"bench": "fig4_support_seeds", "seed": "spread",
                 "ppl": round(max(ppls) - min(ppls), 3)})
    return rows
