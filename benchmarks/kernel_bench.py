"""Pallas-kernel micro-benchmarks (Appendix-E analogue + DESIGN §3).

CPU wall time of interpret-mode kernels is NOT a TPU proxy; what we report
per kernel is (a) allclose parity vs the jnp oracle, (b) the *modeled* HBM
bytes of kernel vs the XLA densify-in-HBM reference — the structural win
the kernel exists for — and (c) interpret-mode wall time for completeness.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import support as support_lib
from repro.kernels import ops, ref


def kernel_rows(d_in: int = 512, d_out: int = 512, r: int = 64,
                m: int = 256, delta: float = 0.03) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    rowsS, colsS = support_lib.sample_support(3, d_in, d_out, delta,
                                              "row_balanced")
    nnz = rowsS.shape[0]
    v = (rng.standard_normal(nnz) * 0.02).astype(np.float32)
    x = jnp.asarray(rng.standard_normal((m, d_in)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((d_in, r)) * 0.02, jnp.float32)
    A = jnp.asarray(rng.standard_normal((r, d_out)) * 0.02, jnp.float32)
    v_t, r_t, c_t, perm = ops.prepare_tiles(rowsS, colsS, v, d_in, d_out)
    scale = 0.25

    # --- sl_matmul ---
    y_ref = ref.sl_matmul_ref(x, B, A, jnp.asarray(rowsS), jnp.asarray(colsS),
                              jnp.asarray(v), scale)
    t0 = time.perf_counter()
    y = ops.sl_matmul(x, B, A, v_t, r_t, c_t, scale)
    jax.block_until_ready(y)
    dt = time.perf_counter() - t0
    err = float(jnp.abs(y - y_ref).max())
    # HBM traffic model (bytes): reference writes + reads dense W (2·d·p·4)
    # on top of x/y/factors; kernel streams factors + tiles only.
    dense_extra = 2 * d_in * d_out * 4
    kern_bytes = (m * d_in + m * d_out + d_in * r + r * d_out) * 4 \
        + v_t.size * 4 + r_t.size * 4 + c_t.size * 4
    rows.append({"bench": "kernel", "name": "sl_matmul",
                 "us_per_call": int(dt * 1e6), "max_err": err,
                 "hbm_bytes_kernel": kern_bytes,
                 "hbm_bytes_xla_densify": kern_bytes + dense_extra,
                 "hbm_saving": round(dense_extra / (kern_bytes + dense_extra),
                                     3)})

    # --- sddmm ---
    dy = jnp.asarray(rng.standard_normal((m, d_out)), jnp.float32)
    dv_ref = ref.sddmm_ref(x, dy, jnp.asarray(rowsS), jnp.asarray(colsS))
    t0 = time.perf_counter()
    dv = ops.sddmm(x, dy, r_t, c_t)
    jax.block_until_ready(dv)
    dt = time.perf_counter() - t0
    # parity: map tile values back to COO order via the layout permutation
    perm_np = np.asarray(perm).reshape(-1)
    flat = np.asarray(dv).reshape(-1)
    recon = np.zeros(nnz, np.float32)
    mask = perm_np >= 0
    recon[perm_np[mask]] = flat[mask]
    rows.append({"bench": "kernel", "name": "sddmm",
                 "us_per_call": int(dt * 1e6),
                 "max_err": float(np.abs(recon - np.asarray(dv_ref)).max()),
                 "hbm_bytes_kernel": (m * (d_in + d_out) + 3 * v_t.size) * 4,
                 "hbm_bytes_xla_densify": (m * (d_in + d_out)
                                           + 2 * d_in * d_out) * 4,
                 "hbm_saving": round(2 * d_in * d_out /
                                     (m * (d_in + d_out) + 2 * d_in * d_out),
                                     3)})

    # --- adam8bit ---
    from repro.optim import quant
    n = 64 * 256
    p = jnp.asarray(rng.standard_normal(n), jnp.float32)
    g = jnp.asarray(rng.standard_normal(n), jnp.float32)
    mc, ms, _ = quant.quantize_blockwise(jnp.zeros(n), 256, True)
    vc, vs, _ = quant.quantize_blockwise(jnp.zeros(n), 256, False)
    kw = dict(lr=1e-3, b1=0.9, b2=0.999, bc1=0.1, bc2=0.001, eps=1e-8, wd=0.0)
    t0 = time.perf_counter()
    out = ops.adam8bit_update(p, g, mc, ms, vc, vs, **kw)
    jax.block_until_ready(out[0])
    dt = time.perf_counter() - t0
    scalars = jnp.array([kw["lr"], kw["b1"], kw["b2"], 1 - kw["b1"],
                         1 - kw["b2"], kw["bc1"], kw["bc2"],
                         kw["eps"], kw["wd"], 0.0])
    rp = ref.adam8bit_ref(p.reshape(-1, 256), g.reshape(-1, 256),
                          mc.reshape(-1, 256), ms, vc.reshape(-1, 256), vs,
                          scalars)[0]
    rows.append({"bench": "kernel", "name": "adam8bit",
                 "us_per_call": int(dt * 1e6),
                 "max_err": float(jnp.abs(out[0] - rp.reshape(-1)).max()),
                 # fused: p r/w + g r + codes r/w (2×1B) + scales; XLA path
                 # round-trips f32 moments: extra 8B/param r+w
                 "hbm_bytes_kernel": n * (4 + 4 + 4 + 4) + 2 * (n * 2),
                 "hbm_bytes_xla_densify": n * (4 + 4 + 4 + 4) + 2 * (n * 2)
                 + n * 16,
                 "hbm_saving": round(16 / (16 + 18), 3)})
    return rows


# ---------------------------------------------------------------------------
# Full train step: exec_mode="fused" vs the densify path
# ---------------------------------------------------------------------------

def _sltrain_traffic_model(params_abs, consts_abs):
    """Modeled per-step HBM parameter-traffic bytes of every SLTrain linear
    under both execution modes (activation traffic is identical and
    excluded). Per matrix and step:

    * densify — the dense W transient is materialized in HBM three times
      (forward matmul, backward dx matmul, backward G = xᵀ·dy for the
      factor/support grads), each a write + read: 6·d_in·d_out·4 bytes.
    * fused — three kernel passes (sl_matmul fwd, sl_matmul dx, sddmm dv)
      each stream only the factored bytes: (d_in+d_out)·r + nnz values
      plus the 3 int32 tile-const arrays.

    Returns (densify_bytes, fused_bytes, param_compression) where
    param_compression is the paper's d·p / ((d+p)·r + nnz) ratio summed
    over all adapted matrices.
    """
    import jax

    from repro.dist.sharding import _path_keys
    leaves = {_path_keys(p): l for p, l in
              jax.tree_util.tree_flatten_with_path(params_abs)[0]}
    cleaves = {_path_keys(p): l for p, l in
               jax.tree_util.tree_flatten_with_path(consts_abs)[0]}
    densify = fused = dense_elems = factored_elems = 0
    for path, B in leaves.items():
        if path[-1] != "B":
            continue
        parent = path[:-1]
        A = leaves[parent + ("A",)]
        v = leaves[parent + ("v",)]
        perm = cleaves.get(parent + ("perm",))
        stack = int(np.prod(B.shape[:-2])) if B.ndim > 2 else 1
        d, r = B.shape[-2:]
        p = A.shape[-1]
        nnz = int(np.prod(v.shape[B.ndim - 2:]))
        tile_elems = int(np.prod(perm.shape[B.ndim - 2:])) if perm is not None else 0
        densify += stack * 6 * d * p * 4
        fused += stack * 3 * (((d + p) * r + nnz) * 4 + 3 * tile_elems * 4)
        dense_elems += stack * d * p
        factored_elems += stack * ((d + p) * r + nnz)
    return densify, fused, dense_elems / max(1, factored_elems)


def train_step_rows(steps: int = 8) -> List[Dict]:
    """Train-step comparison fused vs densify (ISSUE 3 acceptance): loss
    parity over ``steps`` identical-seed steps, modeled HBM parameter
    traffic, and interpret-mode wall time (NOT a TPU proxy — parity and
    the byte model are the signal)."""
    import dataclasses

    import jax

    from repro.configs.base import OptimizerConfig
    from repro.data.pipeline import SyntheticC4
    from repro.models import registry
    from repro.optim import optimizers
    from repro.train import step as step_lib

    base = registry.get_smoke_config("llama_60m")
    base = dataclasses.replace(base, dtype="float32",
                               param=dataclasses.replace(base.param,
                                                         mode="sltrain"))

    def run(exec_mode):
        cfg = dataclasses.replace(
            base, param=dataclasses.replace(base.param, exec_mode=exec_mode))
        api = registry.get_api(cfg)
        params, consts = api.init(cfg, jax.random.PRNGKey(42), seed=42)
        opt = optimizers.make(OptimizerConfig(lr=1e-3, warmup_steps=2,
                                              total_steps=steps))
        opt_state = opt.init(params)
        fn = jax.jit(step_lib.make_train_step(cfg, api, opt))
        data = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)
        losses = []
        t0 = time.perf_counter()
        for _ in range(steps):
            batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
            params, opt_state, metrics = fn(params, opt_state, consts, batch)
            losses.append(float(metrics["loss"]))
        return np.asarray(losses), time.perf_counter() - t0, (params, consts)

    loss_d, wall_d, _ = run("dense")
    loss_f, wall_f, _ = run("fused")

    cfg_f = dataclasses.replace(
        base, param=dataclasses.replace(base.param, exec_mode="fused"))
    params_abs, consts_abs = registry.get_api(cfg_f).init(cfg_f, key=None)
    hbm_densify, hbm_fused, compression = _sltrain_traffic_model(
        params_abs, consts_abs)

    return [{
        "bench": "train_step", "name": "fused_vs_densify", "steps": steps,
        "max_loss_delta": float(np.abs(loss_d - loss_f).max()),
        "final_loss_dense": round(float(loss_d[-1]), 6),
        "final_loss_fused": round(float(loss_f[-1]), 6),
        "wall_s_densify": round(wall_d, 2), "wall_s_fused": round(wall_f, 2),
        "hbm_bytes_densify": hbm_densify, "hbm_bytes_fused": hbm_fused,
        # the structural win: fused parameter traffic beats densify by at
        # least the paper's compression ratio (tile-const overhead is what
        # keeps it from being exactly 6·d·p / 3·factored)
        "hbm_ratio": round(hbm_densify / hbm_fused, 2),
        "param_compression": round(compression, 2),
    }]


# ---------------------------------------------------------------------------
# Per-layer update sweep: update_mode="per_layer" vs "global" (ISSUE 4)
# ---------------------------------------------------------------------------

def perlayer_rows(steps: int = 6) -> List[Dict]:
    """update_mode="per_layer" (repro.train.perlayer) acceptance rows:

    * loss parity vs the global step over ``steps`` identical-seed steps on
      the 60M smoke config (adamw; the sweep's vjp-per-layer grads and the
      LOMO-style two-pass clip must match the monolithic backward),
    * modeled peak grad + optimizer-transient HBM at LLaMA-7B scale
      (Appendix F): the co-resident group drops from O(P_trainable) to
      O(P_layer), and sltrain + adam8bit(fused) + per_layer reproduces the
      paper's ~73% total-memory reduction.
    """
    import dataclasses

    import jax

    from repro.configs.base import OptimizerConfig
    from repro.core import memory
    from repro.data.pipeline import SyntheticC4
    from repro.models import registry
    from repro.optim import optimizers
    from repro.train import perlayer, step as step_lib

    base = registry.get_smoke_config("llama_60m")
    cfg = dataclasses.replace(base, dtype="float32",
                              param=dataclasses.replace(base.param,
                                                        mode="sltrain"))
    api = registry.get_api(cfg)

    def run(update_mode):
        params, consts = api.init(cfg, jax.random.PRNGKey(42), seed=42)
        opt = optimizers.make(OptimizerConfig(lr=1e-3, warmup_steps=2,
                                              total_steps=steps))
        opt_state = opt.init(params)
        if update_mode == "per_layer":
            fn = jax.jit(perlayer.make_perlayer_train_step(cfg, api, opt))
        else:
            fn = jax.jit(step_lib.make_train_step(cfg, api, opt))
        data = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)
        losses = []
        t0 = time.perf_counter()
        for _ in range(steps):
            batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
            params, opt_state, metrics = fn(params, opt_state, consts, batch)
            losses.append(float(metrics["loss"]))
        return np.asarray(losses), time.perf_counter() - t0

    loss_g, wall_g = run("global")
    loss_p, wall_p = run("per_layer")

    # Appendix-F residency model at the paper's 7B scale
    inv_cfg = dict(memory.PAPER_LLAMA["7b"])
    rank = inv_cfg.pop("rank")
    inv = memory.llama_inventory(**inv_cfg)
    kw = dict(optimizer="adam8bit", rank=rank, delta=0.05, index_bytes=4,
              fused_opt=True)
    est_g = memory.training_estimate(inv, "sltrain", update_mode="global",
                                     **kw)
    est_p = memory.training_estimate(inv, "sltrain", update_mode="per_layer",
                                     **kw)
    red = memory.paper_f_reduction("7b", index_bytes=4)

    resid = lambda e: e.grad_bytes + e.transient_bytes
    return [{
        "bench": "train_step", "name": "perlayer_vs_global", "steps": steps,
        "max_loss_delta": float(np.abs(loss_g - loss_p).max()),
        "final_loss_global": round(float(loss_g[-1]), 6),
        "final_loss_perlayer": round(float(loss_p[-1]), 6),
        "wall_s_global": round(wall_g, 2), "wall_s_perlayer": round(wall_p, 2),
        # the structural win: co-resident grad+opt-transient bytes drop
        # from O(P_trainable) to O(P_layer) — the 7B Appendix-F model
        "grad_transient_bytes_global_7b": int(resid(est_g)),
        "grad_transient_bytes_perlayer_7b": int(resid(est_p)),
        "residency_ratio": round(resid(est_g) / resid(est_p), 2),
        "paper_f_total_reduction": round(red["reduction"], 3),
    }]
