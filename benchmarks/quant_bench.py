"""Quantized-serving benchmark: post-training int8 sparse decode
(repro.quant) vs the bf16 sparse path, on a briefly-trained llama_60m.

Rows (snapshotted to BENCH_quant.json by benchmarks/run.py):

* ``greedy_match`` — serve the same prompts through a bf16-sparse engine
  and a quant engine built from the calibrated artifact; report the
  token-level greedy match rate, the mean/max |Δlogit| on a held-out
  batch, and eval ppl under both paths. GATED: match_rate ≥ 0.99 OR
  mean |Δlogit| ≤ MAX_MEAN_ABS_DLOGIT (near-tied logits on a smoke-sized
  model can flip a token without the distribution moving).
* ``decode_bytes`` — modeled HBM bytes one decode step reads for the
  SPARSE term across all quantized matrices (repro.quant.layout
  accounting: 12 B/nnz bf16 tile-CSR → 5 B/nnz + per-channel scales).
  GATED: reduction ≥ 2×.

Both gates are also re-asserted from the committed BENCH_quant.json by
tests/test_quant.py, so the snapshot can't drift stale-green.

  PYTHONPATH=src python -m benchmarks.quant_bench
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.quant import calibrate, layout
from repro.serve.engine import ServeEngine
from repro.train import step as step_lib

Row = Dict[str, object]

#: |Δlogit| bound for the greedy gate's escape hatch — pinned, not tuned
#: per run: int8 with per-channel scales + SVD fold holds the smoke model
#: well under this (measured ~2e-3 mean), while a broken dequant path
#: (wrong scale axis, dropped fold) lands orders of magnitude above.
MAX_MEAN_ABS_DLOGIT = 0.05
MIN_MATCH_RATE = 0.99
MIN_BYTES_REDUCTION = 2.0


def _model_sparse_bytes(cfg, consts) -> Dict[str, int]:
    """Sum the modeled sparse-term decode bytes over every quantized
    matrix (stacked layers count once per slice)."""
    out = {"bf16": 0, "int8": 0}

    def walk(c):
        if isinstance(c, dict):
            if "qv_t" in c:
                qv = np.asarray(c["qv_t"])
                lead = qv.shape[:-3]
                n = int(np.prod(lead)) if lead else 1
                nkt, nnt, _ = qv.shape[-3:]
                d_in, d_out = nkt * layout.TILE, nnt * layout.TILE
                for kind in ("bf16", "int8"):
                    out[kind] += n * layout.sparse_decode_bytes(
                        d_in, d_out, cfg.param.delta, cfg.param.support_kind,
                        quant=(kind == "int8"))
                return
            for v in c.values():
                walk(v)

    walk(consts)
    return out


def quant_rows(arch: str = "llama_60m", steps: int = 60, requests: int = 8,
               new_tokens: int = 16, seed: int = 0) -> List[Row]:
    from benchmarks import tables

    cfg = tables._smoke_cfg("sltrain")
    out = tables._train(cfg, steps)
    params, consts = out["params"], out["consts"]
    qp, qc, qstats = calibrate.calibrate_model(cfg, params, consts)

    # logit delta + ppl on held-out synthetic batches, sparse vs quant
    from repro.data.pipeline import SyntheticC4
    api = registry.get_api(cfg)
    cfg_sp = dataclasses.replace(
        cfg, param=dataclasses.replace(cfg.param, exec_mode="sparse"))
    cfg_q = dataclasses.replace(
        cfg, param=dataclasses.replace(cfg.param, exec_mode="quant"))
    ev_sp = jax.jit(step_lib.make_eval_step(cfg_sp, api))
    ev_q = jax.jit(step_lib.make_eval_step(cfg_q, api))
    data = SyntheticC4(cfg.vocab_size, 64, 8, seed=7)
    ces_sp, ces_q, dmean, dmax = [], [], [], 0.0
    for _ in range(4):
        b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        ces_sp.append(float(ev_sp(params, consts, b)["ce"]))
        ces_q.append(float(ev_q(qp, qc, b)["ce"]))
        lg_sp, _ = api.apply(cfg_sp, params, consts, b)
        lg_q, _ = api.apply(cfg_q, qp, qc, b)
        d = np.abs(np.asarray(lg_sp, np.float32)[..., :cfg.vocab_size]
                   - np.asarray(lg_q, np.float32)[..., :cfg.vocab_size])
        dmean.append(float(d.mean()))
        dmax = max(dmax, float(d.max()))
    ppl_sp = float(np.exp(np.mean(ces_sp)))
    ppl_q = float(np.exp(np.mean(ces_q)))
    mean_dlogit = float(np.mean(dmean))

    # greedy serving parity: identical prompts through both engines
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(3, cfg.vocab_size,
                            size=int(rng.integers(2, 12))).tolist()
               for _ in range(requests)]
    outs = {}
    for label, (c, p, cc) in (("sparse", (cfg, params, consts)),
                              ("quant", (cfg, qp, qc))):
        eng = ServeEngine(c, p, cc, n_slots=4, max_len=64, paged=True,
                          block_len=8,
                          exec_mode="sparse" if label == "sparse" else
                          "quant")
        reqs = [eng.submit(pr, max_new_tokens=new_tokens) for pr in prompts]
        st = eng.run_until_drained()
        assert len(st["completed"]) == requests and not st["exhausted"]
        outs[label] = [r.out for r in reqs]
    total = sum(len(o) for o in outs["sparse"])
    matched = sum(a == b for sa, sb in zip(outs["sparse"], outs["quant"])
                  for a, b in zip(sa, sb))
    match_rate = matched / total

    nbytes = _model_sparse_bytes(cfg, qc)
    reduction = nbytes["bf16"] / nbytes["int8"]

    # the two headline gates (mirrored from BENCH_quant.json by
    # tests/test_quant.py so the committed snapshot stays honest)
    assert match_rate >= MIN_MATCH_RATE or \
        mean_dlogit <= MAX_MEAN_ABS_DLOGIT, (match_rate, mean_dlogit)
    assert reduction >= MIN_BYTES_REDUCTION, reduction

    return [
        {"bench": "quant_serve", "row": "greedy_match",
         "match_rate": round(match_rate, 4),
         "matched_tokens": f"{matched}/{total}",
         "mean_abs_dlogit": round(mean_dlogit, 5),
         "max_abs_dlogit": round(dmax, 4),
         "ppl_bf16": round(ppl_sp, 3), "ppl_int8": round(ppl_q, 3),
         "ppl_rel_delta": round(abs(ppl_q - ppl_sp) / ppl_sp, 5),
         "quant_matrices": qstats["n_matrices"],
         "max_abs_w_err": round(qstats["max_abs_err"], 6),
         "train_steps": steps},
        {"bench": "quant_serve", "row": "decode_bytes",
         "sparse_bytes_per_tok_bf16": nbytes["bf16"],
         "sparse_bytes_per_tok_int8": nbytes["int8"],
         "reduction_x": round(reduction, 2),
         "bytes_per_nnz_bf16": layout.BYTES_PER_NNZ_BF16,
         "bytes_per_nnz_int8": layout.BYTES_PER_NNZ_INT8},
    ]


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)
    for r in quant_rows(steps=args.steps, requests=args.requests,
                        new_tokens=args.new_tokens):
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print("quant_bench: int8 sparse decode matches bf16 greedy tokens "
          "(or stays under the pinned logit bound) and cuts modeled "
          "sparse-term decode bytes >= 2x")


if __name__ == "__main__":
    main()
