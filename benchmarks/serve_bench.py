"""Serve-engine benchmark: paged vs legacy, dense vs sparse decode,
gathered-view vs paged-kernel decode attention.

Reports, per engine configuration:

* **prefill**: jit dispatches per request (legacy pays one per prompt
  token, paged one per admission batch) and prefill tokens/sec;
* **decode**: decode steps, decode tokens/sec, and (``decode_traffic_rows``)
  modeled per-step HBM K/V traffic — the gather path reads
  ``n_slots × view_len`` rows per layer while the paged-attention kernel
  streams only live blocks (kernels/paged_attention.py);
* **correctness**: each request's greedy tokens vs a single-request legacy
  run (ground truth — no slot interference), while per-slot positions
  diverge across the batch (staggered arrivals, mixed prompt lengths);
* **SLO** (:func:`slo_rows`): a seeded Poisson-arrival workload with a
  shared system prompt, reporting p50/p99 TTFT (engine clock ticks) and
  tokens/sec/slot for legacy vs drained-paged vs continuous vs
  continuous+prefix-shared admission, plus the modeled prefill HBM write
  bytes copy-on-write sharing avoids. Percentiles are read from the
  engine's ``repro.obs.metrics`` TTFT histograms (and cross-checked
  against ``np.percentile`` over the raw per-request stamps — exact on
  integer ticks with unit-width buckets).

  PYTHONPATH=src python -m benchmarks.serve_bench
  PYTHONPATH=src python -m benchmarks.serve_bench --requests 12 --new-tokens 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import registry
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import _bucket


def _mk_requests(cfg, n, rng):
    return [rng.integers(3, cfg.vocab_size,
                         size=int(rng.integers(2, 14))).tolist()
            for _ in range(n)]


def _drain_timed(eng, prompts, new_tokens, stagger):
    """Submit (optionally staggered), time prefill-ish and decode phases.

    The engine interleaves admission and decode, so we time the whole
    drain and attribute wall time by dispatch counts × measured per-call
    cost; tokens/sec below uses end-to-end wall time, the honest figure."""
    reqs = []
    t0 = time.perf_counter()
    if stagger:
        it = iter(prompts)
        reqs.append(eng.submit(next(it), max_new_tokens=new_tokens))
        for p in it:
            eng.step()
            reqs.append(eng.submit(p, max_new_tokens=new_tokens))
    else:
        reqs = [eng.submit(p, max_new_tokens=new_tokens) for p in prompts]
    stats = eng.run_until_drained()
    dt = time.perf_counter() - t0
    return reqs, stats, dt


def run(arch="llama_60m", requests=8, new_tokens=16, slots=4, max_len=64,
        block_len=8, seed=0, stagger=True):
    cfg = registry.get_smoke_config(arch)
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    rng = np.random.default_rng(seed)
    prompts = _mk_requests(cfg, requests, rng)
    prompt_toks = sum(len(p) for p in prompts)

    # ground truth: every request alone on a legacy engine (no slot
    # interference, so the legacy shared-index wart cannot corrupt it).
    # One engine, drained between submits: each prefill rewrites every
    # cache position it will attend, and reusing the engine avoids
    # re-jitting the identical decode program per prompt.
    truth = []
    eng = ServeEngine(cfg, params, consts, n_slots=1, max_len=max_len)
    for p in prompts:
        r = eng.submit(p, max_new_tokens=new_tokens)
        eng.run_until_drained()
        truth.append(r.out)

    rows = []
    for label, kw in (
            ("legacy/dense", dict(paged=False)),
            ("paged/dense", dict(paged=True, block_len=block_len)),
            ("paged/sparse", dict(paged=True, block_len=block_len,
                                  sparse_decode=True)),
            ("paged/kernel", dict(paged=True, block_len=block_len,
                                  attn_kernel="paged")),
    ):
        eng = ServeEngine(cfg, params, consts, n_slots=slots,
                          max_len=max_len, **kw)
        # warm the jit caches so drain timing isn't compile time — one
        # drain per distinct prefill bucket the run will hit
        for wp in {_bucket(len(p), 8): p for p in prompts}.values():
            eng.submit(wp, max_new_tokens=2)
            eng.run_until_drained()
        eng.reset_metrics()

        reqs, stats, dt = _drain_timed(eng, prompts, new_tokens,
                                       stagger and kw.get("paged", False))
        out_toks = sum(len(r.out) for r in reqs)
        match = [r.out == t for r, t in zip(reqs, truth)]
        rows.append({
            "engine": label,
            "prefill_dispatches": eng.dispatches["prefill"],
            "prefill_dispatch_per_req": round(
                eng.dispatches["prefill"] / len(prompts), 2),
            "decode_steps": stats["decode_steps"],
            "tok_per_s": round((prompt_toks + out_toks) / dt, 1),
            "tokens_match_single_run": f"{sum(match)}/{len(match)}",
        })
    return rows, prompts


def decode_traffic_rows(arch="llama_60m", requests=8, new_tokens=16, slots=4,
                        max_len=64, block_len=8, seed=0):
    """Modeled per-decode-step HBM K/V traffic: gathered-view vs
    paged-kernel, on the staggered-arrival workload.

    Both engines run the same staggered mix and must emit identical
    tokens (the kernel parity gate — the one MEASURED property here).
    The traffic numbers are a closed-form model of the two read paths,
    driven by the engine's ``kv_traffic`` counters (scheduler state, not
    kernel instrumentation): per K/V row the model charges ``2 (k+v) ×
    Hkv × hd × dtype_bytes`` per layer; the gather path reads ``n_slots ×
    view_len`` rows/step/layer by construction of ``kv.gather_view``, the
    kernel path the attended live positions (whole-block fetch
    granularity is reported separately as ``resident``). By that model
    the reduction equals ``view_len / mean_live_len`` up to idle-slot
    slack — reported as ``gather_over_kernel`` vs the per-active-slot
    bound. The asserts below gate counter WIRING (live ≤ resident ≤
    gather rows) and a concrete regression tripwire (≥ 2× on this
    workload), not the algebraic identity itself.
    """
    cfg = registry.get_smoke_config(arch)
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    rng = np.random.default_rng(seed)
    prompts = _mk_requests(cfg, requests, rng)

    import jax.numpy as jnp
    row_bytes = (2 * cfg.n_kv_heads * cfg.resolved_head_dim
                 * jnp.dtype(cfg.dtype).itemsize * cfg.n_layers)

    outs, traffic = {}, {}
    for ak in ("gather", "paged"):
        eng = ServeEngine(cfg, params, consts, n_slots=slots,
                          max_len=max_len, paged=True, block_len=block_len,
                          attn_kernel=ak)
        reqs, _, _ = _drain_timed(eng, prompts, new_tokens, stagger=True)
        outs[ak] = [r.out for r in reqs]
        traffic[ak] = dict(eng.kv_traffic)
        view_len = eng.layout.view_len
    assert outs["gather"] == outs["paged"], \
        "paged-kernel decode diverged from the gathered-view path"

    t = traffic["paged"]
    steps = t["steps"]
    mean_live = t["live_tokens"] / max(t["active_slots"], 1)
    gather_b = t["gather_tokens"] * row_bytes / steps
    live_b = t["live_tokens"] * row_bytes / steps
    resident_b = t["resident_tokens"] * row_bytes / steps
    ratio = gather_b / live_b
    bound = view_len / mean_live
    # wiring gates: live positions can never exceed their block-rounded
    # residency, residency can never exceed the worst-case view — a
    # miscounted position vector or allocator drift trips these
    assert t["live_tokens"] <= t["resident_tokens"] <= t["gather_tokens"], t
    assert t["resident_tokens"] % block_len == 0, t
    # regression tripwire (NOT the algebraic bound, which both sides of
    # the model satisfy by construction): this staggered mix keeps mean
    # live length well under half the view, so a scheduler/counter change
    # that erodes the paged win shows up as a hard failure here
    assert ratio >= 2.0, (ratio, bound)
    return [
        {"bench": "serve_decode_traffic", "path": "gather_view",
         "hbm_kv_bytes_per_step": round(gather_b), "decode_steps": steps,
         "tokens_match": True},
        {"bench": "serve_decode_traffic", "path": "paged_kernel",
         "hbm_kv_bytes_per_step": round(live_b),
         "hbm_kv_bytes_per_step_block_rounded": round(resident_b),
         "decode_steps": steps, "tokens_match": True},
        {"bench": "serve_decode_traffic", "path": "ratio",
         "gather_over_kernel": round(ratio, 2),
         "view_len_over_mean_live": round(bound, 2),
         "mean_live_len": round(mean_live, 2), "view_len": view_len},
    ]


def _poisson_workload(cfg, rng, requests, shared_prefix_len, shared_every,
                      mean_gap):
    """Seeded Poisson-arrival workload: interarrival gaps ~ Poisson(mean),
    arrivals in engine clock ticks. ``shared_every`` of every
    ``shared_every`` requests reuse one common (block-alignable) prefix —
    the production shared-system-prompt shape; the rest are independent.
    Returns (prompts, arrivals, shared_ids)."""
    prefix = rng.integers(3, cfg.vocab_size, size=shared_prefix_len).tolist()
    prompts, shared_ids = [], []
    for i in range(requests):
        tail = rng.integers(3, cfg.vocab_size,
                            size=int(rng.integers(2, 8))).tolist()
        if i % shared_every != shared_every - 1:
            prompts.append(prefix + tail)
            shared_ids.append(i)
        else:
            prompts.append(tail)
    gaps = rng.poisson(mean_gap, size=requests)
    arrivals = np.cumsum(gaps).tolist()
    return prompts, arrivals, shared_ids


def _drain_arrivals(eng, prompts, arrivals, new_tokens):
    """Drained admission against timed arrivals: batch up whatever has
    arrived by the clock, drain it fully, repeat — requests arriving
    mid-drain wait for the next drain call (the batch-serving baseline
    continuous admission removes)."""
    pending = sorted(zip(prompts, arrivals, range(len(prompts))),
                     key=lambda t: (t[1], t[2]))
    reqs = [None] * len(prompts)
    while pending:
        eng.clock = max(eng.clock, pending[0][1])
        while pending and pending[0][1] <= eng.clock:
            p, a, i = pending.pop(0)
            reqs[i] = eng.submit(p, max_new_tokens=new_tokens, arrival=a)
        eng.run_until_drained()
    return reqs


def slo_rows(arch="llama_60m", requests=8, new_tokens=12, slots=4,
             max_len=64, block_len=8, seed=0, shared_prefix_len=24,
             shared_every=4, mean_gap=2.0):
    """Poisson-arrival SLO harness: p50/p99 time-to-first-token (engine
    clock ticks = jit dispatches, the deterministic serving-time unit) and
    decode tokens/sec/slot for four admission/sharing modes on ONE seeded
    workload, plus the modeled prefill HBM write bytes that copy-on-write
    prefix sharing avoids.

    Modes: ``legacy`` (contiguous cache, per-token prefill, drained),
    ``paged/drained`` (batched prefill, drain-per-batch admission),
    ``paged/continuous`` (run_stream: admission inside the decode loop),
    ``paged/continuous+shared`` (continuous + prefix attach / chunked
    suffix prefill). Every mode must stay token-for-token with the
    single-request ground truth; the asserts additionally gate the two
    headline SLO claims (strictly better p99 TTFT for continuous vs
    drained, prefill-token reduction ≥ (N−1)/N × shared-prefix fraction).
    """
    cfg = registry.get_smoke_config(arch)
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    rng = np.random.default_rng(seed)
    prompts, arrivals, shared_ids = _poisson_workload(
        cfg, rng, requests, shared_prefix_len, shared_every, mean_gap)
    prompt_toks = sum(len(p) for p in prompts)

    # per-request greedy ground truth (no batching interference)
    truth = []
    eng = ServeEngine(cfg, params, consts, n_slots=1, max_len=max_len,
                      paged=True, block_len=block_len)
    for p in prompts:
        r = eng.submit(p, max_new_tokens=new_tokens)
        eng.run_until_drained()
        truth.append(r.out)

    kv_row_bytes = (2 * cfg.n_kv_heads * cfg.resolved_head_dim
                    * np.dtype(cfg.dtype).itemsize * cfg.n_layers)

    modes = (
        ("legacy", dict(paged=False), "drain"),
        ("paged/drained", dict(paged=True, block_len=block_len), "drain"),
        ("paged/continuous", dict(paged=True, block_len=block_len),
         "stream"),
        ("paged/continuous+shared",
         dict(paged=True, block_len=block_len, prefix_sharing=True),
         "stream"),
    )
    rows, stats = [], {}
    for label, kw, loop in modes:
        eng = ServeEngine(cfg, params, consts, n_slots=slots,
                          max_len=max_len, **kw)
        # warm the jit caches (one drain per prefill bucket), then zero
        # every instrument the measurement reads (registry reset — the
        # counter views are read-only)
        for wp in {_bucket(len(p), 8): p for p in prompts}.values():
            eng.submit(wp, max_new_tokens=2)
            eng.run_until_drained()
        eng.reset_metrics()

        t0 = time.perf_counter()
        if loop == "stream":
            reqs = [eng.submit(p, max_new_tokens=new_tokens, arrival=a)
                    for p, a in zip(prompts, arrivals)]
            res = eng.run_stream()
            assert not res["unfinished"], res
        else:
            reqs = _drain_arrivals(eng, prompts, arrivals, new_tokens)
        dt = time.perf_counter() - t0

        # SLO percentiles come from the engine's registry histogram (the
        # obs path IS the measurement); the hand-computed np.percentile
        # over per-request stamps must agree exactly — tick TTFTs are
        # integers on unit-width buckets, where the bucket-count
        # reconstruction is numpy-equivalent (see obs.metrics.Histogram)
        ht = eng.obs.histogram("serve.ttft_ticks")
        ttft = np.array([r.t_first - r.arrival for r in reqs], np.float64)
        p50, p99 = ht.percentile(50), ht.percentile(99)
        assert ht.count == len(reqs), (ht.count, len(reqs))
        assert p50 == float(np.percentile(ttft, 50)), \
            (label, p50, float(np.percentile(ttft, 50)))
        assert p99 == float(np.percentile(ttft, 99)), \
            (label, p99, float(np.percentile(ttft, 99)))
        out_toks = sum(len(r.out) for r in reqs)
        match = sum(r.out == t for r, t in zip(reqs, truth))
        pt = dict(eng.prefill_traffic) if eng.paged else \
            {"tokens_total": prompt_toks, "tokens_prefilled": prompt_toks,
             "tokens_shared": 0}
        stats[label] = {"ttft_hist": ht, "traffic": pt}
        rows.append({
            "bench": "serve_slo", "mode": label,
            "p50_ttft_ticks": p50,
            "p99_ttft_ticks": p99,
            "tok_per_s_per_slot": round(out_toks / dt / slots, 1),
            "prefill_dispatches": eng.dispatches["prefill"],
            "decode_steps": eng._steps,
            "prefill_tokens": pt["tokens_prefilled"],
            "prefill_tokens_shared": pt["tokens_shared"],
            "prefill_hbm_bytes_saved": pt["tokens_shared"] * kv_row_bytes,
            "tokens_match_single_run": f"{match}/{len(prompts)}",
        })

    n = len(prompts)
    for r in rows:
        # legacy is a TIMING baseline only: its shared max(pos) write
        # index corrupts lagging slots on mixed-length batches by design
        # (the wart the paged per-slot index vector removes), so its match
        # column is informational
        if r["mode"] == "legacy":
            continue
        assert r["tokens_match_single_run"] == f"{n}/{n}", \
            f"{r['mode']}: diverged from single-request greedy truth"
    # headline SLO claim: continuous admission strictly beats drained at
    # the tail — a request arriving mid-drain no longer waits out the drain
    p99_c = stats["paged/continuous"]["ttft_hist"].percentile(99)
    p99_d = stats["paged/drained"]["ttft_hist"].percentile(99)
    assert p99_c < p99_d, (p99_c, p99_d)
    # headline sharing claim: with N sharers of one prefix, attach skips
    # ≥ (N−1)/N of the shared-prefix token mass (the first sharer pays)
    pt = stats["paged/continuous+shared"]["traffic"]
    n_sh = len(shared_ids)
    aligned = (shared_prefix_len // block_len) * block_len
    floor = (n_sh - 1) / n_sh * (n_sh * aligned / pt["tokens_total"])
    reduction = pt["tokens_shared"] / pt["tokens_total"]
    # every sharer after the first attaches the full aligned prefix, so
    # the reduction meets the floor EXACTLY when no block was ever evicted
    # between sharers — compare with an ulp of slack
    assert reduction >= floor - 1e-9, (reduction, floor)
    rows.append({
        "bench": "serve_slo", "mode": "sharing_summary",
        "shared_requests": n_sh, "shared_prefix_len": shared_prefix_len,
        "prefill_token_reduction": round(reduction, 3),
        "reduction_floor": round(floor, 3),
        "p99_ttft_continuous": p99_c, "p99_ttft_drained": p99_d,
    })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama_60m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--block-len", type=int, default=8)
    ap.add_argument("--no-stagger", action="store_true",
                    help="submit all requests upfront (positions still "
                         "diverge via mixed prompt lengths)")
    args = ap.parse_args(argv)

    rows, prompts = run(args.arch, args.requests, args.new_tokens,
                        args.slots, args.max_len, args.block_len,
                        stagger=not args.no_stagger)
    lens = sorted(len(p) for p in prompts)
    print(f"# {args.requests} requests, prompt lens {lens}, "
          f"{args.new_tokens} new tokens, {args.slots} slots"
          + ("" if args.no_stagger else ", staggered arrivals (paged)"))
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    # the two headline claims, asserted so CI can run this as a check:
    by = {r["engine"]: r for r in rows}
    assert by["paged/dense"]["prefill_dispatch_per_req"] <= 1.0 < \
        by["legacy/dense"]["prefill_dispatch_per_req"], \
        "batched prefill must be O(1) dispatches per request"
    n = len(prompts)
    assert by["paged/dense"]["tokens_match_single_run"] == f"{n}/{n}", \
        "paged decode must match single-request runs token-for-token"
    assert by["paged/sparse"]["tokens_match_single_run"] == f"{n}/{n}", \
        "sparse paged decode must match single-request runs token-for-token"
    assert by["paged/kernel"]["tokens_match_single_run"] == f"{n}/{n}", \
        "paged-attention-kernel decode must match single-request runs " \
        "token-for-token"
    for r in decode_traffic_rows(args.arch, args.requests, args.new_tokens,
                                 args.slots, args.max_len, args.block_len):
        print(",".join(f"{k}={v}" for k, v in r.items()))
    for r in slo_rows(args.arch, args.requests, args.new_tokens,
                      args.slots, args.max_len, args.block_len):
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print("serve_bench: paged prefill O(1)/req; paged+sparse and "
          "paged-kernel outputs match single-request ground truth; kernel "
          "decode HBM K/V traffic ≥ view_len/mean_live below gather; "
          "continuous admission beats drained at p99 TTFT; prefix sharing "
          "skips ≥ (N-1)/N of the shared prompt mass")


if __name__ == "__main__":
    main()
