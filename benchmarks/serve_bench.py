"""Serve-engine benchmark: paged vs legacy, dense vs sparse decode.

Reports, per engine configuration:

* **prefill**: jit dispatches per request (legacy pays one per prompt
  token, paged one per admission batch) and prefill tokens/sec;
* **decode**: decode steps, decode tokens/sec;
* **correctness**: each request's greedy tokens vs a single-request legacy
  run (ground truth — no slot interference), while per-slot positions
  diverge across the batch (staggered arrivals, mixed prompt lengths).

  PYTHONPATH=src python -m benchmarks.serve_bench
  PYTHONPATH=src python -m benchmarks.serve_bench --requests 12 --new-tokens 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import registry
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import _bucket


def _mk_requests(cfg, n, rng):
    return [rng.integers(3, cfg.vocab_size,
                         size=int(rng.integers(2, 14))).tolist()
            for _ in range(n)]


def _drain_timed(eng, prompts, new_tokens, stagger):
    """Submit (optionally staggered), time prefill-ish and decode phases.

    The engine interleaves admission and decode, so we time the whole
    drain and attribute wall time by dispatch counts × measured per-call
    cost; tokens/sec below uses end-to-end wall time, the honest figure."""
    reqs = []
    t0 = time.perf_counter()
    if stagger:
        it = iter(prompts)
        reqs.append(eng.submit(next(it), max_new_tokens=new_tokens))
        for p in it:
            eng.step()
            reqs.append(eng.submit(p, max_new_tokens=new_tokens))
    else:
        reqs = [eng.submit(p, max_new_tokens=new_tokens) for p in prompts]
    stats = eng.run_until_drained()
    dt = time.perf_counter() - t0
    return reqs, stats, dt


def run(arch="llama_60m", requests=8, new_tokens=16, slots=4, max_len=64,
        block_len=8, seed=0, stagger=True):
    cfg = registry.get_smoke_config(arch)
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    rng = np.random.default_rng(seed)
    prompts = _mk_requests(cfg, requests, rng)
    prompt_toks = sum(len(p) for p in prompts)

    # ground truth: every request alone on a legacy engine (no slot
    # interference, so the legacy shared-index wart cannot corrupt it).
    # One engine, drained between submits: each prefill rewrites every
    # cache position it will attend, and reusing the engine avoids
    # re-jitting the identical decode program per prompt.
    truth = []
    eng = ServeEngine(cfg, params, consts, n_slots=1, max_len=max_len)
    for p in prompts:
        r = eng.submit(p, max_new_tokens=new_tokens)
        eng.run_until_drained()
        truth.append(r.out)

    rows = []
    for label, kw in (
            ("legacy/dense", dict(paged=False)),
            ("paged/dense", dict(paged=True, block_len=block_len)),
            ("paged/sparse", dict(paged=True, block_len=block_len,
                                  sparse_decode=True)),
    ):
        eng = ServeEngine(cfg, params, consts, n_slots=slots,
                          max_len=max_len, **kw)
        # warm the jit caches so drain timing isn't compile time — one
        # drain per distinct prefill bucket the run will hit
        for wp in {_bucket(len(p), 8): p for p in prompts}.values():
            eng.submit(wp, max_new_tokens=2)
            eng.run_until_drained()
        eng.dispatches = {"prefill": 0, "decode": 0}
        eng._steps = 0
        eng.completed.clear()

        reqs, stats, dt = _drain_timed(eng, prompts, new_tokens,
                                       stagger and kw.get("paged", False))
        out_toks = sum(len(r.out) for r in reqs)
        match = [r.out == t for r, t in zip(reqs, truth)]
        rows.append({
            "engine": label,
            "prefill_dispatches": eng.dispatches["prefill"],
            "prefill_dispatch_per_req": round(
                eng.dispatches["prefill"] / len(prompts), 2),
            "decode_steps": stats["decode_steps"],
            "tok_per_s": round((prompt_toks + out_toks) / dt, 1),
            "tokens_match_single_run": f"{sum(match)}/{len(match)}",
        })
    return rows, prompts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama_60m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--block-len", type=int, default=8)
    ap.add_argument("--no-stagger", action="store_true",
                    help="submit all requests upfront (positions still "
                         "diverge via mixed prompt lengths)")
    args = ap.parse_args(argv)

    rows, prompts = run(args.arch, args.requests, args.new_tokens,
                        args.slots, args.max_len, args.block_len,
                        stagger=not args.no_stagger)
    lens = sorted(len(p) for p in prompts)
    print(f"# {args.requests} requests, prompt lens {lens}, "
          f"{args.new_tokens} new tokens, {args.slots} slots"
          + ("" if args.no_stagger else ", staggered arrivals (paged)"))
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    # the two headline claims, asserted so CI can run this as a check:
    by = {r["engine"]: r for r in rows}
    assert by["paged/dense"]["prefill_dispatch_per_req"] <= 1.0 < \
        by["legacy/dense"]["prefill_dispatch_per_req"], \
        "batched prefill must be O(1) dispatches per request"
    n = len(prompts)
    assert by["paged/dense"]["tokens_match_single_run"] == f"{n}/{n}", \
        "paged decode must match single-request runs token-for-token"
    assert by["paged/sparse"]["tokens_match_single_run"] == f"{n}/{n}", \
        "sparse paged decode must match single-request runs token-for-token"
    print("serve_bench: paged prefill O(1)/req; paged+sparse outputs match "
          "single-request ground truth")


if __name__ == "__main__":
    main()
