"""Benchmark harness: one benchmark per paper table (deliverable (d)).

Prints CSV rows ``name,us_per_call,derived``. Training-backed tables are
scaled to CPU (smoke configs, synthetic C4); the memory tables use the
paper's exact Appendix-F accounting at full model sizes.

Each benchmark's rows are additionally snapshotted to a machine-readable
``BENCH_<group>.json`` at the repo root (``--no-snapshots`` to skip), so
the perf trajectory is diffable across PRs instead of living in
CHANGES.md prose. Related benches share a group file (the two serve
benches → BENCH_serve.json, the two train-step benches →
BENCH_train.json); everything else snapshots under its own name.
Snapshots are ``{"meta": {...}, "rows": [...]}`` — the meta header
(git sha + commit count, UTC timestamp, jax version, device kind) makes
each number attributable to the exact tree and machine that produced it.

  PYTHONPATH=src python -m benchmarks.run            # full (few minutes)
  PYTHONPATH=src python -m benchmarks.run --quick    # memory+kernels only
  PYTHONPATH=src python -m benchmarks.run --only table2_memory
  PYTHONPATH=src python -m benchmarks.run --only serve_slo,serve_decode_traffic
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _snapshot_meta() -> dict:
    """Provenance header for BENCH_*.json: which tree, when, on what.
    Every field degrades to None rather than failing — a snapshot from a
    tarball (no git) or an exotic backend is still a snapshot."""
    import datetime
    import subprocess

    def _git(*args):
        try:
            return subprocess.run(
                ("git", "-C", str(REPO_ROOT)) + args, check=True,
                capture_output=True, text=True, timeout=10).stdout.strip()
        except Exception:
            return None

    meta = {
        "git_sha": _git("rev-parse", "--short", "HEAD"),
        "git_commits": (lambda c: int(c) if c else None)(
            _git("rev-list", "--count", "HEAD")),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    try:
        import jax
        dev = jax.devices()[0]
        meta["jax_version"] = jax.__version__
        meta["device_kind"] = dev.device_kind
        meta["platform"] = dev.platform
    except Exception:
        meta.update(jax_version=None, device_kind=None, platform=None)
    return meta

# benches whose rows land in one shared snapshot file
SNAPSHOT_GROUPS = {
    "serve_decode_traffic": "serve",
    "serve_slo": "serve",
    "train_step_fused": "train",
    "train_step_perlayer": "train",
}


def _emit(rows):
    for r in rows:
        name = r.get("bench", "?")
        sub = [f"{k}={v}" for k, v in r.items() if k != "bench"]
        us = r.get("us_per_call", r.get("us_per_step", ""))
        print(f"{name},{us},{';'.join(sub)}")
    sys.stdout.flush()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--no-snapshots", action="store_true",
                    help="skip writing BENCH_<group>.json snapshots")
    args = ap.parse_args(argv)

    from benchmarks import kernel_bench, quant_bench, serve_bench, tables

    all_benches = {
        "table2_memory": tables.table2_memory,
        "kernels": kernel_bench.kernel_rows,
        "train_step_fused": kernel_bench.train_step_rows,
        "train_step_perlayer": kernel_bench.perlayer_rows,
        "serve_decode_traffic": serve_bench.decode_traffic_rows,
        "serve_slo": serve_bench.slo_rows,
        "quant": quant_bench.quant_rows,
        "table1_support": tables.table1_support,
        "table2_ppl": tables.table2_ppl,
        "table3_throughput": tables.table3_throughput,
        "table5_inference": tables.table5_inference,
        "table6_ablation": tables.table6_ablation,
        "fig4_support_seeds": tables.fig4_support_seeds,
    }
    quick = {"table2_memory", "kernels", "train_step_fused",
             "train_step_perlayer", "serve_decode_traffic", "serve_slo",
             "quant", "table3_throughput", "table5_inference"}

    selected = list(all_benches)
    if args.only:
        selected = args.only.split(",")
        unknown = [n for n in selected if n not in all_benches]
        if unknown:
            ap.error(f"unknown bench(es) {unknown}; "
                     f"known: {sorted(all_benches)}")
    elif args.quick:
        selected = [k for k in all_benches if k in quick]

    print("name,us_per_call,derived")
    collected, groups = [], {}
    for name in selected:
        t0 = time.time()
        rows = all_benches[name]()
        _emit(rows)
        collected += rows
        groups.setdefault(SNAPSHOT_GROUPS.get(name, name), []).extend(rows)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if not args.no_snapshots:
        meta = _snapshot_meta()
        for group, rows in groups.items():
            path = REPO_ROOT / f"BENCH_{group}.json"
            with open(path, "w") as f:
                json.dump({"meta": meta, "rows": rows}, f, indent=1,
                          default=str, sort_keys=True)
                f.write("\n")
            print(f"# snapshot: {path.name} ({len(rows)} rows)", flush=True)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(collected, f, indent=1, default=str)


if __name__ == "__main__":
    main()
