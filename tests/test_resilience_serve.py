"""Serve-side resilience: deadlines, load shedding, slot-stall fault
injection, bounded-run budget exhaustion, and the quant->sparse
validated fallback. Invariant under test everywhere: every submitted
request reaches a TERMINAL status — the engine never silently loses
one — and cancelled requests release their KV pages."""
import jax
import numpy as np
import pytest

from repro.models import registry
from repro.resilience import ChaosEngine
from repro.serve.engine import ServeEngine

TERMINAL = ("done", "rejected", "timed_out", "failed")


@pytest.fixture(scope="module")
def model():
    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    return cfg, params, consts


def _engine(model, **kw):
    cfg, params, consts = model
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("paged", True)
    kw.setdefault("block_len", 8)
    return ServeEngine(cfg, params, consts, **kw)


def _all_blocks_free(eng):
    return eng.sched.blocks.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------

def test_deadline_ticks_cancels_and_releases_pages(model):
    eng = _engine(model, deadline_ticks=4)
    fast = eng.submit([5, 9], max_new_tokens=2)
    slow = eng.submit([7, 11], max_new_tokens=50)
    stats = eng.run_until_drained()
    assert fast.status == "done"
    assert slow.status == "timed_out" and not slow.done
    assert "deadline" in slow.fail_reason
    assert slow.t_done is not None
    assert not eng._has_work()
    assert _all_blocks_free(eng), "timed-out request pinned KV blocks"
    snap = eng.obs.snapshot()
    assert snap["serve.deadline_exceeded"]["value"] == 1
    assert stats["summary"] == {"done": 1, "timed_out": 1}
    assert stats["timed_out"] == [slow]


def test_per_request_deadline_overrides_engine_default(model):
    eng = _engine(model, deadline_ticks=100)
    tight = eng.submit([5, 9], max_new_tokens=50, deadline_ticks=3)
    loose = eng.submit([7, 11], max_new_tokens=6)
    eng.run_until_drained()
    assert tight.status == "timed_out"
    assert loose.status == "done" and len(loose.out) == 6


def test_queued_request_can_time_out_before_admission(model):
    # 1 slot: the queued request's deadline lapses while it waits
    eng = _engine(model, n_slots=1, deadline_ticks=5)
    first = eng.submit([5, 9], max_new_tokens=12)
    waiting = eng.submit([7, 11], max_new_tokens=4)
    eng.run_until_drained()
    assert first.status == "timed_out"       # 12 tokens > 5-tick budget
    assert waiting.status in ("done", "timed_out")
    assert "queued" in waiting.fail_reason if \
        waiting.status == "timed_out" else True
    assert not eng._has_work() and _all_blocks_free(eng)


# ---------------------------------------------------------------------------
# Load shedding
# ---------------------------------------------------------------------------

def test_max_queue_sheds_with_structured_rejection(model):
    eng = _engine(model, max_queue=2)
    reqs = [eng.submit([3 + i, 7], max_new_tokens=3) for i in range(5)]
    shed = [r for r in reqs if r.status == "rejected"]
    assert len(shed) == 3
    for r in shed:
        assert "max_queue=2" in r.fail_reason
    stats = eng.run_until_drained()
    assert all(r.status in TERMINAL for r in reqs)     # none silently lost
    assert sum(r.status == "done" for r in reqs) == 2
    snap = eng.obs.snapshot()
    assert snap["serve.rejected"]["value"] == 3
    assert stats["summary"] == {"done": 2, "rejected": 3}
    assert stats["rejected"] == shed


# ---------------------------------------------------------------------------
# Slot stalls (chaos fault injection)
# ---------------------------------------------------------------------------

def test_stall_delays_but_preserves_output(model):
    """A stalled slot freezes; the engine decodes around it and the
    stalled request resumes with IDENTICAL tokens (greedy decode, K/V
    isolation) — the fault costs latency, never correctness."""
    prompts = [[5, 9, 11], [7, 13]]
    ref = _engine(model)
    ref_reqs = [ref.submit(p, max_new_tokens=6) for p in prompts]
    ref.run_until_drained()

    chaos = ChaosEngine.parse("stall@2:5", seed=3)
    eng = _engine(model, tick_hook=chaos.serve_hook)
    chaos.bind(eng.obs)
    reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    stats = eng.run_until_drained()
    assert all(r.status == "done" for r in reqs)
    assert [r.out for r in reqs] == [r.out for r in ref_reqs]
    assert stats["summary"] == {"done": 2}
    snap = eng.obs.snapshot()
    assert snap["resilience.faults_injected{kind=stall}"]["value"] == 1
    # the stall cost ticks: completion is strictly later than the
    # unstalled run for at least one request
    assert max(r.t_done for r in reqs) > max(r.t_done for r in ref_reqs)


def test_stall_past_deadline_drains_with_zero_wedged(model):
    """Stall one slot for longer than the deadline: the victim times out
    (pages released), everything else completes, the engine drains —
    nothing spins forever even when EVERY active slot is stalled."""
    chaos = ChaosEngine.parse("stall@3:50", seed=0)
    eng = _engine(model, n_slots=1, deadline_ticks=12,
                  tick_hook=chaos.serve_hook)
    chaos.bind(eng.obs)
    reqs = [eng.submit([3 + i, 7], max_new_tokens=4) for i in range(3)]
    stats = eng.run_until_drained(max_steps=500)
    assert not stats["exhausted"]
    assert not eng._has_work() and _all_blocks_free(eng)
    assert all(r.status in ("done", "timed_out") for r in reqs)
    snap = eng.obs.snapshot()
    assert snap["serve.deadline_exceeded"]["value"] >= 1
    assert snap["resilience.faults_injected{kind=stall}"]["value"] == 1


def test_stall_legacy_engine(model):
    chaos = ChaosEngine.parse("stall@2:3", seed=1)
    eng = _engine(model, paged=False, tick_hook=chaos.serve_hook)
    chaos.bind(eng.obs)
    reqs = [eng.submit([5 + i, 9], max_new_tokens=4) for i in range(2)]
    eng.run_until_drained()
    assert all(r.status == "done" and len(r.out) == 4 for r in reqs)
    snap = eng.obs.snapshot()
    assert snap["resilience.faults_injected{kind=stall}"]["value"] == 1


# ---------------------------------------------------------------------------
# Budget exhaustion: failed is terminal but resumable
# ---------------------------------------------------------------------------

def test_budget_exhaustion_marks_failed_then_resumes(model):
    eng = _engine(model, n_slots=1)
    reqs = [eng.submit([3 + i, 7], max_new_tokens=6) for i in range(3)]
    with pytest.warns(UserWarning, match="max_steps"):
        stats = eng.run_until_drained(max_steps=2)
    assert stats["exhausted"]
    survivors = stats["unfinished"]
    assert survivors
    for r in survivors:
        assert r.status == "failed"
        assert "max_steps=2" in r.fail_reason
    assert stats["summary"]["failed"] == len(survivors)
    # calling the run loop again REVIVES and finishes them
    stats2 = eng.run_until_drained()
    assert not stats2["exhausted"]
    assert all(r.status == "done" and len(r.out) == 6 for r in reqs)
    assert stats2["summary"] == {"done": 3}


def test_run_stream_budget_exhaustion(model):
    eng = _engine(model)
    reqs = [eng.submit([3 + i, 7], max_new_tokens=8, arrival=i)
            for i in range(3)]
    stats = eng.run_stream(max_steps=3)
    assert stats["exhausted"]
    assert all(r.status == "failed" for r in stats["unfinished"])
    stats2 = eng.run_stream()
    assert not stats2["exhausted"]
    assert all(r.status == "done" for r in reqs)


# ---------------------------------------------------------------------------
# Quant fallback
# ---------------------------------------------------------------------------

def test_quant_without_artifact_still_raises_by_default(model):
    cfg, params, consts = model
    with pytest.raises(ValueError, match="calibrated consts"):
        ServeEngine(cfg, params, consts, exec_mode="quant")


def test_quant_fallback_degrades_to_sparse_and_serves(model):
    cfg, params, consts = model
    with pytest.warns(UserWarning, match="degraded"):
        eng = ServeEngine(cfg, params, consts, exec_mode="quant",
                          quant_fallback=True, n_slots=1, max_len=32)
    assert eng.quant_fell_back
    assert eng.cfg.param.exec_mode == "sparse"
    assert eng.obs.snapshot()["serve.quant_fallback"]["value"] == 1
    r = eng.submit([5, 9, 11], max_new_tokens=4)
    eng.run_until_drained()
    assert r.status == "done" and len(r.out) == 4
    # and the degraded path is the VALIDATED bf16 sparse decode: same
    # tokens as an engine built sparse on purpose
    ref = ServeEngine(cfg, params, consts, exec_mode="sparse", n_slots=1,
                      max_len=32)
    r2 = ref.submit([5, 9, 11], max_new_tokens=4)
    ref.run_until_drained()
    assert r.out == r2.out
