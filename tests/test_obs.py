"""Tests for the unified observability layer (ISSUE 7): the
repro.obs.metrics registry (counters/gauges/bucket histograms, labels,
snapshot, JSONL sink, jit-safety), repro.obs.trace (span recorder +
Chrome-trace validation), and the serve/train rewiring on top of them —
engine counter-view backward compatibility, per-request tick-span
geometry reproducing tick TTFT exactly, and trainer gauges."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry as model_registry
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.engine import ServeEngine


# ---------------------------------------------------------------------------
# metrics: instruments
# ---------------------------------------------------------------------------

def test_counter_gauge_basics_and_labels():
    reg = obs_metrics.Registry()
    c = reg.counter("t.count")
    c.inc()
    c.inc(3)
    assert c.value == 4 and isinstance(c.value, int)
    with pytest.raises(ValueError):
        c.inc(-1)

    d = reg.counter("t.disp")
    d.labels(phase="prefill").inc(2)
    d.labels(phase="decode").inc()
    # family value aggregates the children; same labels → same child
    assert d.value == 3
    assert d.labels(phase="prefill").value == 2
    assert d.labels(phase="prefill") is d.labels(phase="prefill")

    g = reg.gauge("t.gauge")
    assert g.value is None
    g.set(2.5)
    assert g.value == 2.5

    snap = reg.snapshot()
    assert snap["t.count"] == {"type": "counter", "value": 4}
    assert snap["t.disp{phase=decode}"]["value"] == 1
    assert snap["t.disp{phase=prefill}"]["value"] == 2
    assert snap["t.gauge"]["value"] == 2.5
    json.dumps(snap)  # plain-JSON contract

    # get-or-create is idempotent; type conflicts are loud
    assert reg.counter("t.count") is c
    with pytest.raises(TypeError):
        reg.gauge("t.count")


def test_registry_reset_keeps_handles_live():
    reg = obs_metrics.Registry()
    c = reg.counter("t.c")
    h = reg.histogram("t.h", buckets=obs_metrics.tick_buckets(8))
    c.inc(5)
    h.observe(3)
    reg.reset()
    assert c.value == 0 and h.count == 0
    c.inc()  # the cached handle still feeds the registered instrument
    assert reg.snapshot()["t.c"]["value"] == 1


def test_histogram_percentiles_exact_for_integer_ticks():
    """On unit-width integer buckets the bucket-count reconstruction is
    numpy-equivalent: every sample sits exactly at its bucket bound."""
    rng = np.random.default_rng(0)
    data = rng.integers(0, 500, size=317)
    h = obs_metrics.Histogram("t.ticks", buckets=obs_metrics.tick_buckets())
    for v in data:
        h.observe(int(v))
    assert h.count == len(data)
    for q in (0, 25, 50, 90, 99, 100):
        assert h.percentile(q) == float(np.percentile(data, q)), q


def test_histogram_percentiles_within_bucket_width_for_floats():
    rng = np.random.default_rng(1)
    data = rng.lognormal(1.0, 1.5, size=400)  # ms-ish latencies
    bounds = obs_metrics.ms_buckets()
    h = obs_metrics.Histogram("t.ms", buckets=bounds)
    for v in data:
        h.observe(float(v))
    for q in (50, 90, 99):
        est, ref = h.percentile(q), float(np.percentile(data, q))
        # the estimate sits at/under its bucket's upper bound and the true
        # value lies in the same (or an interpolated-adjacent) bucket
        i = np.searchsorted(bounds, ref)
        lo = 0.0 if i == 0 else bounds[i - 1]
        hi = bounds[min(i, len(bounds) - 1)]
        assert lo <= est <= hi * (1 + 1e-12), (q, est, ref, lo, hi)


def test_histogram_edge_cases_and_bucket_conflicts():
    reg = obs_metrics.Registry()
    h = reg.histogram("t.h", buckets=(1.0, 2.0))
    assert math.isnan(h.percentile(50))
    h.observe(99.0)  # overflow bucket, represented at the last bound
    assert h.percentile(50) == 2.0
    row = reg.snapshot()["t.h"]
    assert row["buckets"] == [["+Inf", 1]] and row["count"] == 1
    with pytest.raises(ValueError):
        reg.histogram("t.h", buckets=(1.0, 3.0))


def test_instruments_reject_tracers_accept_concrete_jax():
    """The jit-safety contract: concrete jax arrays coerce (host transfer
    at the call site), tracers raise instead of leaking into host state."""
    reg = obs_metrics.Registry()
    c = reg.counter("t.c")
    c.inc(jnp.asarray(2.0))
    reg.gauge("t.g").set(jax.jit(lambda x: x * 2)(jnp.float32(1.5)))
    assert c.value == 2 and reg.gauge("t.g").value == 3.0

    def traced(x):
        c.inc(x)  # x is a tracer here
        return x

    with pytest.raises(TypeError, match="tracer|coerced"):
        jax.jit(traced)(jnp.float32(1.0))
    assert c.value == 2  # nothing leaked


def test_write_jsonl_appends_parseable_lines(tmp_path):
    reg = obs_metrics.Registry()
    reg.counter("t.c").inc(7)
    path = tmp_path / "m.jsonl"
    reg.write_jsonl(str(path), extra={"step": 1})
    reg.counter("t.c").inc()
    reg.write_jsonl(str(path), extra={"step": 2})
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert [l["step"] for l in lines] == [1, 2]
    assert lines[0]["metrics"]["t.c"]["value"] == 7
    assert lines[1]["metrics"]["t.c"]["value"] == 8
    assert all("ts" in l for l in lines)


def test_prometheus_text_counters_gauges_and_sanitization():
    reg = obs_metrics.Registry()
    reg.counter("serve.dispatches", help="jit dispatches").inc(3)
    g = reg.gauge("train.loss")
    g.set(1.5)
    reg.gauge("train.unset")           # never set → no sample line
    reg.counter("0weird-name").inc()
    text = reg.to_prometheus_text()
    lines = text.splitlines()
    assert text.endswith("\n")
    # names sanitized: dots/dashes → _, leading digit prefixed
    assert "# HELP serve_dispatches jit dispatches" in lines
    assert "# TYPE serve_dispatches counter" in lines
    assert "serve_dispatches 3" in lines
    assert "train_loss 1.5" in lines
    assert "_0weird_name 1" in lines
    # unset gauge: TYPE header only, no sample
    assert "# TYPE train_unset gauge" in lines
    assert not any(l.startswith("train_unset ") for l in lines)
    # families render in sorted name order
    assert lines.index("# TYPE _0weird_name counter") < \
        lines.index("# TYPE serve_dispatches counter")


def test_prometheus_text_label_escaping_and_ordering():
    reg = obs_metrics.Registry()
    c = reg.counter("t.labeled")
    # labels are stored sorted by key regardless of kwargs order, and
    # values escape backslash, quote, and newline per the text format
    c.labels(zeta="z", alpha='say "hi"\n\\end').inc(2)
    c.labels(zeta="other", alpha="a").inc()
    text = reg.to_prometheus_text()
    assert ('t_labeled{alpha="say \\"hi\\"\\n\\\\end",zeta="z"} 2'
            in text.splitlines())
    assert 't_labeled{alpha="a",zeta="other"} 1' in text.splitlines()
    # the two children each get exactly one sample line; no parent sample
    assert sum(l.startswith("t_labeled{") for l in text.splitlines()) == 2
    assert not any(l.startswith("t_labeled ") for l in text.splitlines())


def test_prometheus_text_histogram_cumulative_buckets():
    reg = obs_metrics.Registry()
    h = reg.histogram("t.lat", buckets=(1.0, 2.0, 5.0))
    for v in (0.5, 1.5, 1.7, 4.0, 99.0):   # one overflow sample
        h.observe(v)
    lines = reg.to_prometheus_text().splitlines()
    assert "# TYPE t_lat histogram" in lines
    # le buckets are CUMULATIVE and end at +Inf == _count
    assert 't_lat_bucket{le="1"} 1' in lines
    assert 't_lat_bucket{le="2"} 3' in lines
    assert 't_lat_bucket{le="5"} 4' in lines
    assert 't_lat_bucket{le="+Inf"} 5' in lines
    assert "t_lat_count 5" in lines
    assert any(l.startswith("t_lat_sum 106.7") for l in lines)
    # a labeled histogram emits per-child series with the le label LAST
    h2 = reg.histogram("t.lab", buckets=(1.0,))
    h2.labels(phase="x").observe(0.5)
    lines = reg.to_prometheus_text().splitlines()
    assert 't_lab_bucket{phase="x",le="1"} 1' in lines
    assert 't_lab_bucket{phase="x",le="+Inf"} 1' in lines
    assert 't_lab_count{phase="x"} 1' in lines


# ---------------------------------------------------------------------------
# trace: spans + schema validation
# ---------------------------------------------------------------------------

def test_trace_spans_export_and_validate(tmp_path):
    tr = obs_trace.Trace(enabled=True)
    with tr.span("phase.a", cat="test", detail=1):
        with tr.span("phase.b", cat="test"):
            pass
    tr.instant("marker", note="x")
    tr.thread_name(7, "request 7")
    tr.event("tick.span", ts_us=1000, dur_us=2000, tid=7, cat="request")
    doc = tr.to_dict()
    assert obs_trace.validate(doc) == 5
    names = [e["name"] for e in doc["traceEvents"]]
    assert {"phase.a", "phase.b", "marker", "thread_name",
            "tick.span"} <= set(names)
    a = next(e for e in doc["traceEvents"] if e["name"] == "phase.a")
    b = next(e for e in doc["traceEvents"] if e["name"] == "phase.b")
    assert a["ph"] == "X" and a["args"] == {"detail": 1}
    # nesting: b opens after a and closes before it
    assert a["ts"] <= b["ts"] and b["ts"] + b["dur"] <= a["ts"] + a["dur"]

    path = tmp_path / "trace.json"
    assert tr.export(str(path)) == 5
    assert obs_trace.validate_file(str(path)) == 5


def test_trace_disabled_is_noop_and_validation_catches_garbage():
    tr = obs_trace.Trace(enabled=False)
    with tr.span("x"):
        pass
    tr.instant("y")
    tr.event("z", ts_us=0, dur_us=1, tid=1)
    assert tr.events == []
    with pytest.raises(ValueError):
        obs_trace.validate({"traceEvents": []})
    with pytest.raises(ValueError):  # missing tid
        obs_trace.validate([{"name": "a", "ph": "X", "ts": 0, "pid": 1,
                             "dur": 1}])
    with pytest.raises(ValueError):  # complete event without dur
        obs_trace.validate([{"name": "a", "ph": "X", "ts": 0, "pid": 1,
                             "tid": 1}])
    with pytest.raises(ValueError):  # negative timestamp
        obs_trace.validate([{"name": "a", "ph": "i", "ts": -1, "pid": 1,
                             "tid": 1}])


# ---------------------------------------------------------------------------
# engine rewiring: counter views, reset, request trace geometry
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = model_registry.get_smoke_config("llama_60m")
    api = model_registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    return cfg, api, params, consts


def test_engine_counter_views_backward_compatible(model):
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32, paged=True)
    eng.submit([5, 9, 11], max_new_tokens=3)
    eng.run_until_drained()

    # the three legacy dicts read exactly as before through MetricView
    assert eng.dispatches["prefill"] == 1
    assert eng.dispatches["decode"] > 0
    assert dict(eng.prefill_traffic) == {"tokens_total": 3,
                                         "tokens_prefilled": 3,
                                         "tokens_shared": 0}
    assert set(eng.kv_traffic) == {"steps", "gather_tokens", "live_tokens",
                                   "resident_tokens", "active_slots"}
    assert all(isinstance(v, int) for v in dict(eng.kv_traffic).values())

    # ... but they are views now: no assignment, no item mutation
    with pytest.raises(AttributeError):
        eng.dispatches = {"prefill": 0, "decode": 0}
    with pytest.raises(TypeError):
        eng.dispatches["prefill"] = 0

    eng.reset_metrics()
    assert dict(eng.dispatches) == {"prefill": 0, "decode": 0}
    assert eng.obs.histogram("serve.ttft_ticks").count == 0
    assert eng.clock == 0 and eng.completed == []

    # the engine still serves correctly after a reset
    r = eng.submit([5, 9, 11], max_new_tokens=3)
    eng.run_until_drained()
    assert len(r.out) == 3 and eng.dispatches["prefill"] == 1


def test_engine_histograms_and_wall_stamps(model):
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32, paged=True)
    arrivals = [0, 1, 3]
    reqs = [eng.submit([7, 3, 2, 8][: 2 + i], max_new_tokens=3, arrival=a)
            for i, a in enumerate(arrivals)]
    eng.run_stream()

    ht = eng.obs.histogram("serve.ttft_ticks")
    assert ht.count == len(reqs)
    ticks = np.array([r.t_first - r.arrival for r in reqs], np.float64)
    assert ht.percentile(50) == float(np.percentile(ticks, 50))
    assert ht.percentile(99) == float(np.percentile(ticks, 99))

    hw = eng.obs.histogram("serve.ttft_wall_ms")
    assert hw.count == len(reqs) and hw.sum > 0
    for r in reqs:
        assert r.wall_arrival is not None
        assert r.wall_first is not None and r.wall_done is not None
        assert r.wall_arrival <= r.wall_first <= r.wall_done
    # scheduler instruments share the engine registry
    snap = eng.obs.snapshot()
    assert snap["serve.sched.admitted_batch"]["count"] > 0
    assert snap["serve.requests.completed"]["value"] == len(reqs)


def test_engine_request_trace_reproduces_tick_ttft(model):
    """Acceptance: exported per-request spans, laid out at TICK_US per
    engine tick, reproduce each request's tick TTFT exactly."""
    cfg, api, params, consts = model
    tr = obs_trace.Trace(enabled=True)
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32, paged=True,
                      trace=tr)
    reqs = [eng.submit([5, 9, 11, 4][: 2 + i % 2], max_new_tokens=3,
                       arrival=i) for i in range(4)]
    eng.run_stream()

    doc = tr.to_dict()
    obs_trace.validate(doc)
    for req in reqs:
        lane = {e["name"]: e for e in doc["traceEvents"]
                if e.get("tid") == req.uid and e.get("cat") == "request"}
        assert {"queued", "prefill", "decode"} <= set(lane)
        q, pf, dec = lane["queued"], lane["prefill"], lane["decode"]
        # ttft = end of prefill minus start of queued, in ticks
        ttft_trace = (pf["ts"] + pf["dur"] - q["ts"]) / obs_trace.TICK_US
        assert ttft_trace == req.t_first - req.arrival, req.uid
        # lifecycle spans tile the request's lifetime contiguously
        assert q["ts"] == req.arrival * obs_trace.TICK_US
        assert q["ts"] + q["dur"] == pf["ts"]
        assert pf["ts"] + pf["dur"] == dec["ts"]
        assert dec["ts"] + dec["dur"] == req.t_done * obs_trace.TICK_US
        assert pf["args"]["ttft_ticks"] == req.t_first - req.arrival
    # engine phase spans rode along on the wall clock
    phases = {e["name"] for e in doc["traceEvents"]
              if e.get("cat") == "engine"}
    assert {"serve.admission", "serve.prefill_dispatch",
            "serve.decode_dispatch", "serve.block_until_ready"} <= phases


# ---------------------------------------------------------------------------
# trainer rewiring
# ---------------------------------------------------------------------------

def test_trainer_gauges_spans_and_jsonl(tmp_path):
    import dataclasses

    from repro.configs.base import OptimizerConfig, TrainConfig
    from repro.train.trainer import Trainer

    cfg = dataclasses.replace(model_registry.get_smoke_config("llama_60m"),
                              dtype="float32")
    tc = TrainConfig(model=cfg, steps=3, seq_len=16, global_batch=2,
                     log_every=1, ckpt_every=0,
                     ckpt_dir=str(tmp_path / "ckpt"),
                     optim=OptimizerConfig(name="adamw", lr=1e-3,
                                           warmup_steps=2, total_steps=3))
    tr = obs_trace.Trace(enabled=True)
    mpath = tmp_path / "metrics.jsonl"
    t = Trainer(tc, log_fn=lambda *_: None, trace=tr,
                metrics_out=str(mpath))
    t.run()

    snap = t.obs.snapshot()
    assert snap["train.steps"]["value"] == 3
    assert snap["train.tokens"]["value"] == 3 * 2 * 16
    assert snap["train.loss"]["value"] == pytest.approx(
        t.metrics_history[-1]["loss"])
    assert snap["train.lr"]["value"] == pytest.approx(
        t.metrics_history[-1]["lr"])
    assert 0 < snap["train.mfu"]["value"] < 1
    assert snap["train.tokens_per_sec"]["value"] > 0
    assert snap["train.step_ms"]["count"] == 3
    for phase in ("data", "dispatch", "sync"):
        assert snap[f"train.phase_ms{{phase={phase}}}"]["count"] == 3

    lines = [json.loads(l) for l in mpath.read_text().splitlines()]
    assert [l["step"] for l in lines] == [1, 2, 3]

    obs_trace.validate(tr.to_dict())
    steps = [e for e in tr.events if e["name"] == "train.step"]
    assert [e["args"]["step"] for e in steps] == [1, 2, 3]
    for sub in ("train.data", "train.dispatch", "train.sync"):
        assert sum(e["name"] == sub for e in tr.events) == 3
