"""Shared test setup: put ``src`` on sys.path and install the jax
forward-compat shims (``jax.shard_map``, ``jax.sharding.AxisType``,
``make_mesh(axis_types=...)``) before any test module touches jax."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
# repo root, so tests can import the benchmarks/ modules they exercise
sys.path.insert(1, os.path.join(os.path.dirname(__file__), os.pardir))

import repro.dist  # noqa: E402,F401  (import side effect: compat shims)
