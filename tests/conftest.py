"""Shared test setup: put ``src`` on sys.path and install the jax
forward-compat shims (``jax.shard_map``, ``jax.sharding.AxisType``,
``make_mesh(axis_types=...)``) before any test module touches jax."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
# repo root, so tests can import the benchmarks/ modules they exercise
sys.path.insert(1, os.path.join(os.path.dirname(__file__), os.pardir))

import repro.dist  # noqa: E402,F401  (import side effect: compat shims)


def pytest_report_header(config):
    """Say up front whether the property tests run on real hypothesis or
    the seeded-loop fallback (tests/_propshim.py) — so a CI log always
    records which engine produced the run."""
    try:
        import hypothesis
        return f"property tests: hypothesis {hypothesis.__version__}"
    except ImportError:
        return ("property tests: hypothesis NOT installed — seeded-loop "
                "fallback (tests/_propshim.py; no shrinking)")
