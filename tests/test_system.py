"""System-level tests: data pipeline, checkpoint/restart, trainer fault
tolerance, serve engine, gradient compression, memory estimator."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import OptimizerConfig, TrainConfig
from repro.core import memory as memory_lib
from repro.data.pipeline import SyntheticC4
from repro.models import registry
from repro.train.trainer import Trainer, StepTimeWatchdog


def _tc(tmp, steps=6, ckpt_every=0, **kw):
    cfg = registry.get_smoke_config("llama_60m")
    return TrainConfig(model=cfg,
                       optim=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=steps),
                       global_batch=4, seq_len=32, steps=steps,
                       log_every=100, ckpt_every=ckpt_every, ckpt_dir=tmp,
                       async_ckpt=False, **kw)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_host_sharded():
    full = SyntheticC4(512, 64, 8, seed=1).next_batch()["tokens"]
    h0 = SyntheticC4(512, 64, 8, seed=1, host_id=0, num_hosts=2)
    h1 = SyntheticC4(512, 64, 8, seed=1, host_id=1, num_hosts=2)
    assert (np.concatenate([h0.next_batch()["tokens"],
                            h1.next_batch()["tokens"]]) == full).all()


def test_data_checkpoint_roundtrip():
    ds = SyntheticC4(512, 64, 4, seed=3)
    ds.next_batch(); ds.next_batch()
    st = ds.state_dict()
    b3 = ds.next_batch()["tokens"]
    ds2 = SyntheticC4(512, 64, 4, seed=3)
    ds2.restore(st)
    assert (ds2.next_batch()["tokens"] == b3).all()


def test_data_tokens_in_range():
    b = SyntheticC4(512, 128, 4, seed=0).next_batch()["tokens"]
    assert b.min() >= 0 and b.max() < 512


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_ckpt_atomic_and_gc():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        tree = {"w": jnp.arange(8, dtype=jnp.float32),
                "b": jnp.ones(3, jnp.bfloat16) * 1.5}
        for s in (1, 2, 3):
            cm.save(s, tree, config_hash="h")
        assert cm.all_steps() == [2, 3]
        out, man = cm.restore(tree, config_hash="h")
        assert out["b"].dtype == jnp.bfloat16
        assert float(out["b"][0]) == 1.5


def test_ckpt_rejects_config_drift():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(1, {"w": jnp.zeros(4)}, config_hash="aaa")
        with pytest.raises(ValueError, match="config hash"):
            cm.restore({"w": jnp.zeros(4)}, config_hash="bbb")


def test_ckpt_rejects_shape_drift():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(1, {"w": jnp.zeros(4)})
        with pytest.raises(ValueError, match="shape"):
            cm.restore({"w": jnp.zeros(5)})


def test_ckpt_elastic_restore_onto_sharding():
    """Checkpoint written unsharded restores onto a mesh sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
        cm.save(1, tree)
        mesh = jax.make_mesh((1,), ("model",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        sh = {"w": NamedSharding(mesh, P("model", None))}
        out, _ = cm.restore(tree, shardings=sh)
        assert out["w"].sharding == sh["w"]
        assert (np.asarray(out["w"]) == np.arange(16).reshape(4, 4)).all()


# ---------------------------------------------------------------------------
# Trainer: resume bit-exactness, fault hooks, straggler watchdog
# ---------------------------------------------------------------------------

def test_trainer_loss_decreases():
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(_tc(d, steps=30))
        tr.run()
        first = np.mean([m["loss"] for m in tr.metrics_history[:5]])
        last = np.mean([m["loss"] for m in tr.metrics_history[-5:]])
        assert last < first, (first, last)


def test_trainer_kill_resume_bit_exact():
    """Crash at step 5, relaunch, final params must equal an uninterrupted
    run (checkpoint/restart correctness, DESIGN §7)."""
    class Boom(Exception):
        pass

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        # uninterrupted reference
        ref = Trainer(_tc(d1, steps=8, ckpt_every=4))
        ref_state = ref.run()

        def bomb(step):
            if step == 5 and not os.environ.get("_RESUMED"):
                raise Boom()

        tr = Trainer(_tc(d2, steps=8, ckpt_every=4), fault_hook=bomb)
        with pytest.raises(Boom):
            tr.run()
        os.environ["_RESUMED"] = "1"
        try:
            tr2 = Trainer(_tc(d2, steps=8, ckpt_every=4))
            state2 = tr2.run()
        finally:
            del os.environ["_RESUMED"]
        for a, b in zip(jax.tree.leaves(ref_state.params),
                        jax.tree.leaves(state2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog_flags_slow_steps():
    events = []
    wd = StepTimeWatchdog(factor=3.0,
                          on_straggler=lambda s, dt, med: events.append(s))
    for i in range(10):
        wd.observe(i, 0.1)
    assert not wd.flagged
    wd.observe(10, 0.5)
    assert wd.flagged == [10] and events == [10]


# ---------------------------------------------------------------------------
# Serve engine
# ---------------------------------------------------------------------------

def test_serve_engine_continuous_batching():
    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    from repro.serve.engine import ServeEngine
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32)
    reqs = [eng.submit([3 + i, 7], max_new_tokens=3) for i in range(5)]
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_sparse_decode_matches_dense_decode():
    """exec_mode=sparse must produce the same tokens as dense decode."""
    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    from repro.serve.engine import ServeEngine
    outs = []
    for sparse in (False, True):
        eng = ServeEngine(cfg, params, consts, n_slots=1, max_len=32,
                          sparse_decode=sparse)
        r = eng.submit([5, 9, 11], max_new_tokens=6)
        eng.run_until_drained()
        outs.append(r.out)
    assert outs[0] == outs[1], outs


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_int8_psum_error_bound():
    from jax.sharding import PartitionSpec as P
    from repro.dist.compression import int8_psum
    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    f = jax.shard_map(lambda x: int8_psum(x, "pod"), mesh=mesh,
                      in_specs=P(), out_specs=P(), check_vma=False)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(4096),
                    jnp.float32)
    y = f(x)
    # error ≤ one quant step = blockmax/127 per element
    step = float(jnp.abs(x).max()) / 127.0
    assert float(jnp.abs(y - x).max()) <= step + 1e-6


def test_compression_wire_bytes_model():
    from repro.dist.compression import wire_bytes
    n = 1 << 20
    # 2-pod DCI: int8 gather ≈ 1 B/elem vs f32 ring all-reduce 4 B/elem
    assert wire_bytes(n, compressed=True, n_participants=2) <         0.3 * wire_bytes(n, compressed=False, n_participants=2)


# ---------------------------------------------------------------------------
# Memory estimator reproduces the paper
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("size,method,paper_params_M,paper_total_G", [
    ("60m", "full", 58, 0.35), ("60m", "sltrain", 44, 0.26),
    ("130m", "sltrain", 97, 0.60), ("350m", "sltrain", 194, 1.24),
    ("1b", "sltrain", 646, 4.16), ("1b", "full", 1339, 8.04),
    ("1b", "lowrank", 609, 3.66),
])
def test_memory_matches_paper_table2(size, method, paper_params_M,
                                     paper_total_G):
    est = memory_lib.paper_table8(size)[method]
    assert abs(est["params_M"] - paper_params_M) / paper_params_M < 0.02
    assert abs(est["total_G"] - paper_total_G) < 0.06 * paper_total_G + 0.02


def test_relora_periodic_merge_in_trainer():
    """ReLoRA mode: the trainer merges BA into W0 every relora_period steps
    and restarts factors + their Adam moments (paper baseline [32])."""
    import dataclasses
    cfg = registry.get_smoke_config("llama_60m")
    cfg = dataclasses.replace(
        cfg, param=dataclasses.replace(cfg.param, mode="relora",
                                       relora_period=3))
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(model=cfg,
                         optim=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                               total_steps=7),
                         global_batch=4, seq_len=32, steps=7, log_every=100,
                         ckpt_every=0, ckpt_dir=d, async_ckpt=False)
        tr = Trainer(tc)
        state = tr.run()
        # after the merge at step 6 + one step of training, B is one Adam
        # step away from zero — tiny compared to a never-merged B
        b_leaves = [np.asarray(l) for p, l in
                    jax.tree_util.tree_flatten_with_path(state.params)[0]
                    if any(getattr(k, "key", "") == "B" for k in p)]
        assert b_leaves, "no relora factors found"
        assert max(np.abs(b).max() for b in b_leaves) < 1e-2
        # loss still finite and decreasing-ish across merges
        assert np.isfinite(tr.metrics_history[-1]["loss"])


def test_galore_composes_with_sltrain_factors():
    """Paper §3.3: GaLore's low-rank gradient projection can be applied ON
    TOP of the SLTrain factors — the B/A moments then live in an even
    lower-dimensional space."""
    from repro.optim import optimizers as opt_lib
    cfg = registry.get_smoke_config("llama_60m")  # sltrain mode, rank 8
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    oc = OptimizerConfig(name="galore_adamw", lr=1e-3, galore_rank=4,
                         warmup_steps=1, total_steps=5)
    opt = opt_lib.make(oc)
    st = opt.init(params)
    # at least one factor leaf must have a projected (rank-4) moment
    projected = [l for p, l in jax.tree_util.tree_flatten_with_path(
        st["leaves"])[0] if any(getattr(k, "key", "") == "P" for k in p)]
    assert projected, "no projected moments on SLTrain factors"
    from repro.train import step as step_lib
    from repro.data.pipeline import SyntheticC4
    tstep = jax.jit(step_lib.make_train_step(cfg, api, opt))
    data = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)
    import jax.numpy as jnp_
    b = {k: jnp_.asarray(v) for k, v in data.next_batch().items()}
    p2, st2, metrics = tstep(params, st, consts, b)
    assert np.isfinite(float(metrics["loss"]))


def test_compressed_dp_step_trains():
    """Hierarchical DP with int8 cross-pod gradient compression: loss must
    decrease and params stay finite (DESIGN §4 pod-axis compression)."""
    from repro.optim import optimizers as opt_lib
    from repro.train import step as step_lib
    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    oc = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    opt = opt_lib.make(oc)
    opt_state = opt.init(params)
    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    step = jax.jit(step_lib.make_compressed_dp_step(cfg, api, opt, mesh))
    data = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)
    losses = []
    for _ in range(10):
        b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step(params, opt_state, consts, b)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0], losses
