"""Tests for the repro.dist subsystem: compat layer, spec engine,
compressed collectives, and the compressed-DP step round trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import compat, compression, sharding as shl
from repro.models import registry


def _pod_mesh():
    return compat.make_mesh((1,), ("pod",),
                            axis_types=(compat.AxisType.Auto,))


# ---------------------------------------------------------------------------
# compat
# ---------------------------------------------------------------------------

def test_compat_shard_map_accepts_both_check_spellings():
    mesh = _pod_mesh()
    x = jnp.arange(8, dtype=jnp.float32)
    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        f = compat.shard_map(lambda v: jax.lax.psum(v, "pod"), mesh=mesh,
                             in_specs=P(), out_specs=P(), **kw)
        np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x))


def test_forward_compat_names_installed():
    # conftest imports repro.dist, which installs the shims
    assert hasattr(jax, "shard_map")
    assert hasattr(jax.sharding, "AxisType")
    jax.make_mesh((1,), ("pod",),
                  axis_types=(jax.sharding.AxisType.Auto,))


# ---------------------------------------------------------------------------
# spec engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh2():
    return shl.make_local_mesh()


class _Key:
    def __init__(self, key):
        self.key = key


def _spec(path_names, shape, mesh, dtype=jnp.bfloat16):
    leaf = jax.ShapeDtypeStruct(shape, dtype)
    return shl.spec_for_param(tuple(_Key(k) for k in path_names), leaf, mesh)


def test_spec_sltrain_factor_leaves(mesh2):
    # B replicated; A output-sharded over model; support row-sharded
    assert _spec(("layers", "k0", "attn", "wq", "B"), (4, 64, 8),
                 mesh2) == P(None, None, None)
    sA = _spec(("layers", "k0", "attn", "wq", "A"), (4, 8, 64), mesh2)
    assert sA[-1] == ("model",)
    sv = _spec(("layers", "k0", "attn", "wq", "v"), (4, 64, 3), mesh2)
    assert sv[1] == ("model",)
    sc = _spec(("layers", "k0", "attn", "wq", "cols"), (4, 64, 3), mesh2,
               jnp.int32)
    assert sc[1] == ("model",)


def test_spec_dense_and_replicated_leaves(mesh2):
    sw = _spec(("layers", "k0", "mlp", "down", "w"), (4, 128, 64), mesh2)
    assert sw == P(None, None, ("model",))
    assert _spec(("embed",), (512, 64), mesh2) == P(None, None)
    assert _spec(("layers", "k0", "ln_attn"), (4, 64), mesh2) == P(None, None)
    assert _spec(("layers", "k0", "moe", "router", "w"), (4, 64, 8),
                 mesh2) == P(None, None, None)


def test_spec_moe_expert_stack_on_model_axis(mesh2):
    # (L, E, d_in, d_out): expert dim takes the model axis (EP), matrix
    # dims stay unsharded so the axis is not used twice
    se = _spec(("layers", "k0", "moe", "experts", "gate", "w"),
               (4, 8, 64, 128), mesh2)
    assert se == P(None, ("model",), None, None)
    sb = _spec(("layers", "k0", "moe", "experts", "gate", "B"),
               (4, 8, 64, 4), mesh2)
    assert sb == P(None, ("model",), None, None)


def test_param_specs_iid_support_not_row_sharded(mesh2):
    # layer-stacked iid COO support is (L, nnz) — shape-identical to
    # row-balanced (d_in, k); the sibling "rows" leaf must force the COO
    # rule (replicated) instead of sharding the layer dim over model
    sds = jax.ShapeDtypeStruct
    consts = {"layers": {"wq": {
        "rows": sds((4, 512), jnp.int32),
        "cols": sds((4, 512), jnp.int32),
    }}}
    params = {"layers": {"wq": {"v": sds((4, 512), jnp.bfloat16)}}}
    merged = {"layers": {"wq": {**consts["layers"]["wq"],
                                **params["layers"]["wq"]}}}
    specs = shl.param_specs(merged, mesh2)
    for leaf_name in ("rows", "cols", "v"):
        spec = specs["layers"]["wq"][leaf_name]
        assert all(s is None for s in spec), (leaf_name, spec)
    # the row-balanced form (no rows sibling) still row-shards
    rb = shl.param_specs({"wq": {"v": sds((64, 3), jnp.bfloat16),
                                 "cols": sds((64, 3), jnp.int32)}}, mesh2)
    assert rb["wq"]["v"][0] == ("model",)


def test_param_specs_match_tree_and_cover_moe():
    mesh = shl.make_local_mesh()
    cfg = registry.get_config("deepseek_moe_16b")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, key=None)  # abstract, no alloc
    specs = shl.param_specs(params, mesh)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree_util.tree_flatten_with_path(specs)[0]
    assert len(flat_p) == len(flat_s)
    for (path, leaf), (_, spec) in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)


def test_opt_state_specs_mirror_params():
    mesh = shl.make_local_mesh()
    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    params, _ = api.init(cfg, key=None)
    from repro.configs.base import OptimizerConfig
    from repro.optim import optimizers
    opt = optimizers.make(OptimizerConfig())
    opt_abs = jax.eval_shape(opt.init, params)
    p_specs = shl.param_specs(params, mesh)
    o_specs = shl.opt_state_specs(opt_abs, p_specs, mesh)
    # mu mirrors params: same spec on a factor-A leaf; scalars replicated
    flat_p = {shl._path_keys(p): s for p, s in
              jax.tree_util.tree_flatten_with_path(
                  p_specs, is_leaf=lambda x: isinstance(x, P))[0]}
    flat_o = {shl._path_keys(p): s for p, s in
              jax.tree_util.tree_flatten_with_path(
                  o_specs, is_leaf=lambda x: isinstance(x, P))[0]}
    for keys, spec in flat_p.items():
        assert flat_o[("mu",) + keys] == spec
    assert flat_o[("step",)] == P()


def test_cache_specs_batch_and_heads():
    mesh = shl.make_local_mesh()
    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    cache = api.init_cache(cfg, 2, 16, abstract=True)
    specs = shl.cache_specs(cache, mesh, batch_axes=("data",))
    for _, spec in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]:
        assert spec[-4] == ("data",)       # batch dim sharded
        assert spec[-3] is None            # seq replicated (not seq_sharded)


def test_cache_specs_paged_heads_sharded_blocks_replicated():
    """Paged pools (lead, n_blocks, block_len, heads, hd): heads take the
    model axis (TP attention layout carries over to the gathered view);
    the block and block_len dims stay replicated."""
    mesh = shl.make_local_mesh()
    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    cache = api.init_cache(cfg, 2, 32, abstract=True, paged=True,
                           block_len=8)
    specs = shl.cache_specs(cache, mesh, paged=True)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert flat, "empty paged cache spec tree"
    for path, spec in flat:
        assert spec[-2] == ("model",), (path, spec)   # heads sharded (TP)
        assert spec[-4] is None and spec[-3] is None  # pages replicated
        assert spec[-1] is None
    # indivisible heads fall back to replication, never an error. The spec
    # engine only reads axis_names/shape, so a 2-wide stand-in mesh works
    # on a 1-device CPU.
    import dataclasses

    class _TPMesh:
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 2}

    cfg3 = dataclasses.replace(cfg, n_heads=3, n_kv_heads=3, d_model=48)
    cache3 = api.init_cache(cfg3, 2, 32, abstract=True, paged=True,
                            block_len=8)
    for _, spec in jax.tree_util.tree_flatten_with_path(
            shl.cache_specs(cache3, _TPMesh(), paged=True),
            is_leaf=lambda x: isinstance(x, P))[0]:
        assert spec[-2] is None
    # and 4 kv-heads on the same 2-wide mesh do shard
    cache4 = api.init_cache(cfg, 2, 32, abstract=True, paged=True,
                            block_len=8)
    for _, spec in jax.tree_util.tree_flatten_with_path(
            shl.cache_specs(cache4, _TPMesh(), paged=True),
            is_leaf=lambda x: isinstance(x, P))[0]:
        assert spec[-2] == ("model",)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_psum_tree_compressed_matches_exact():
    mesh = _pod_mesh()
    rng = np.random.default_rng(0)
    tree = {
        "big": jnp.asarray(rng.standard_normal(4096), jnp.float32),
        "small": jnp.asarray(rng.standard_normal(16), jnp.float32),
        "ints": jnp.arange(2048, dtype=jnp.int32),
    }
    run = lambda compress: compat.shard_map(
        lambda t: compression.psum_tree(t, "pod", compress=compress),
        mesh=mesh, in_specs=(jax.tree.map(lambda _: P(), tree),),
        out_specs=jax.tree.map(lambda _: P(), tree), check_vma=False)(tree)
    exact = run(False)
    comp = run(True)
    # small float + int leaves bypass quantization entirely
    np.testing.assert_array_equal(np.asarray(comp["small"]),
                                  np.asarray(exact["small"]))
    np.testing.assert_array_equal(np.asarray(comp["ints"]),
                                  np.asarray(exact["ints"]))
    # big float leaf: within one block-quantization step
    err = np.abs(np.asarray(comp["big"]) - np.asarray(exact["big"]))
    step = np.abs(np.asarray(tree["big"])).reshape(-1, 256).max(axis=1) / 127
    assert (err.reshape(-1, 256) <= step[:, None] + 1e-6).all()


def test_wire_bytes_int8_beats_f32_ring():
    n = 1 << 20
    for p in (2, 4):
        c = compression.wire_bytes(n, compressed=True, n_participants=p)
        f = compression.wire_bytes(n, compressed=False, n_participants=p)
        assert c > 0 and f > 0
    # the acceptance bar: ≥3× reduction at 2 pods
    c2 = compression.wire_bytes(n, compressed=True, n_participants=2)
    f2 = compression.wire_bytes(n, compressed=False, n_participants=2)
    assert f2 / c2 >= 3.0


# ---------------------------------------------------------------------------
# compressed-DP step: CPU-mesh round trip on llama_60m
# ---------------------------------------------------------------------------

def test_compressed_dp_step_cpu_mesh_roundtrip():
    from repro.configs.base import OptimizerConfig
    from repro.data.pipeline import SyntheticC4
    from repro.optim import optimizers as opt_lib
    from repro.train import step as step_lib

    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    opt = opt_lib.make(OptimizerConfig(lr=1e-3, warmup_steps=1,
                                       total_steps=4))
    opt_state = opt.init(params)
    mesh = compat.make_mesh((1,), ("pod",),
                            axis_types=(compat.AxisType.Auto,))
    step = jax.jit(step_lib.make_compressed_dp_step(cfg, api, opt, mesh))
    data = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)
    p0 = jax.tree.leaves(params)[0]
    losses = []
    for _ in range(3):
        b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step(params, opt_state, consts, b)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    # the step actually applied updates
    assert not np.array_equal(np.asarray(p0, np.float32),
                              np.asarray(jax.tree.leaves(params)[0],
                                         np.float32))


# ---------------------------------------------------------------------------
# ISSUE 8: fsdp × TP spec composition
# ---------------------------------------------------------------------------

class _FsdpMesh:
    """Fake multi-device mesh (spec logic only reads axis_names/shape) so
    divisibility is exercised without 128 real devices."""
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 8, "model": 8}


def _axis_uses(spec):
    out = []
    for s in spec:
        out.extend(s if isinstance(s, tuple) else ((s,) if s else ()))
    return out


_ALL_ARCHS = registry.PAPER_ARCHS + registry.ARCHS


@pytest.mark.parametrize("arch", _ALL_ARCHS)
def test_fsdp_specs_never_reuse_a_mesh_axis(arch):
    """Property (ISSUE 8 satellite): for every registry config, fsdp × TP
    param/opt specs use each mesh axis AT MOST once per leaf, and every
    sharded dim divides by its axis product (the _guard contract)."""
    cfg = registry.get_smoke_config(arch)
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, key=None)
    mesh = _FsdpMesh()
    for tree in (params, consts):
        specs = shl.param_specs(tree, mesh, fsdp_axes=("data",))
        flat_p = jax.tree_util.tree_flatten_with_path(tree)[0]
        flat_s = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]
        assert len(flat_p) == len(flat_s)
        for (path, leaf), (_, spec) in zip(flat_p, flat_s):
            uses = _axis_uses(spec)
            assert len(uses) == len(set(uses)), (path, spec)
            for dim, s in zip(leaf.shape, spec):
                n = shl.axis_size(mesh, s)
                assert dim % n == 0, (path, spec, leaf.shape)


@pytest.mark.parametrize("arch", _ALL_ARCHS)
def test_named_shardings_accept_fsdp_param_trees(arch):
    """Property: named_shardings materializes a NamedSharding for every
    leaf of every registry config's param tree under fsdp=True on a real
    mesh (specs must be structurally valid for jax, not just our rules)."""
    from jax.sharding import NamedSharding

    cfg = registry.get_smoke_config(arch)
    api = registry.get_api(cfg)
    params, _ = api.init(cfg, key=None)
    mesh = shl.make_local_mesh()
    specs = shl.param_specs(params, mesh, fsdp_axes=("data",))
    nss = shl.named_shardings(mesh, specs)
    for (path, leaf), (_, ns) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(
                nss, is_leaf=lambda x: isinstance(x, NamedSharding))[0]):
        assert isinstance(ns, NamedSharding), path
        # the sharding is consistent with the leaf's rank/shape
        ns.shard_shape(leaf.shape)


def test_fsdp_opt_state_specs_follow_params():
    """AdamW moments inherit the fsdp param spec; adam8bit codes/scales
    (non-mirroring leaves) shard dim 0 over the fsdp axes when divisible."""
    from repro.configs.base import OptimizerConfig
    from repro.optim import optimizers as opt_lib

    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    mesh = _FsdpMesh()
    p_specs = shl.param_specs(params, mesh, fsdp_axes=("data",))

    opt = opt_lib.make(OptimizerConfig(name="adamw"))
    st = opt.init(params)
    s_specs = shl.opt_state_specs(st, p_specs, mesh, fsdp_axes=("data",))
    # the embed moment mirrors the embed param spec exactly
    assert s_specs["mu"]["embed"] == p_specs["embed"]
    # moments never reuse an axis either
    for _, spec in jax.tree_util.tree_flatten_with_path(
            s_specs, is_leaf=lambda x: isinstance(x, P))[0]:
        uses = _axis_uses(spec)
        assert len(uses) == len(set(uses)), spec


# ---------------------------------------------------------------------------
# wire model vs measured HLO (ISSUE 8 acceptance) — needs 2 host devices,
# so it runs scripts/hostmesh_smoke.py in a subprocess with its own
# xla_force_host_platform_device_count
# ---------------------------------------------------------------------------

def test_wire_model_matches_hlo_measured_collectives():
    """dist/compression.wire_bytes (the int8 exchange model) must agree
    with the collective bytes parsed from the compiled compressed-DP
    step's post-SPMD HLO, within ring-algorithm tolerance."""
    import os
    import re
    import subprocess
    import sys

    root = os.path.join(os.path.dirname(__file__), "..")
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    out = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "hostmesh_smoke.py"),
         "--part", "wire"],
        capture_output=True, text=True, timeout=560, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    m = re.search(r"wire_model_ratio=([\d.]+)", out.stdout)
    assert m, out.stdout
    ratio = float(m.group(1))
    assert 0.7 <= ratio <= 1.3, (ratio, out.stdout)
