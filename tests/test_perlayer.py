"""End-to-end + unit tests for ``update_mode="per_layer"`` (ISSUE 4):
repro.train.perlayer layer-wise backward with in-sweep optimizer updates,
the Optimizer per-layer slice API, the Appendix-F memory estimator
extension, and the grad-accum metrics bugfix."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.core import memory
from repro.data.pipeline import SyntheticC4
from repro.models import registry
from repro.optim import optimizers
from repro.train import perlayer, step as step_lib


def _smoke_cfg(exec_mode="dense", arch="llama_60m"):
    base = registry.get_smoke_config(arch)
    return dataclasses.replace(
        base, dtype="float32",
        param=dataclasses.replace(base.param, mode="sltrain",
                                  exec_mode=exec_mode))


def _run_training(cfg, steps, *, update_mode, opt_name="adamw",
                  fused_opt=None, remat="none"):
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(42), seed=42)
    opt = optimizers.make(OptimizerConfig(name=opt_name, lr=1e-3,
                                          warmup_steps=2, total_steps=steps))
    opt_state = opt.init(params)
    if update_mode == "per_layer":
        fn = jax.jit(perlayer.make_perlayer_train_step(
            cfg, api, opt, remat=remat, fused_opt=fused_opt))
    else:
        fn = jax.jit(step_lib.make_train_step(cfg, api, opt, remat=remat))
    data = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)
    losses, gnorms = [], []
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, metrics = fn(params, opt_state, consts, batch)
        losses.append(float(metrics["loss"]))
        gnorms.append(float(metrics["grad_norm"]))
    return np.asarray(losses), np.asarray(gnorms), (params, opt_state)


# ---------------------------------------------------------------------------
# Acceptance: 20-step token-for-token parity vs the global update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("exec_mode", ["dense", "fused"])
def test_perlayer_matches_global_adamw(exec_mode):
    """Same seed, same data, 20 steps, dense AND fused exec: the per-layer
    sweep (per-layer vjp grads, LOMO-style two-pass clip, in-sweep slice
    updates) must track the monolithic value_and_grad + global update to
    float-noise — every step."""
    steps = 20
    cfg = _smoke_cfg(exec_mode)
    loss_g, gn_g, _ = _run_training(cfg, steps, update_mode="global")
    loss_p, gn_p, _ = _run_training(cfg, steps, update_mode="per_layer")
    np.testing.assert_allclose(loss_p, loss_g, rtol=0, atol=2e-5)
    np.testing.assert_allclose(gn_p, gn_g, rtol=1e-5, atol=0)


def test_perlayer_matches_global_adam8bit():
    """Quantized state slices along the layer axis (whole q-blocks per
    layer) must be bitwise-equivalent to the global 8-bit update; the
    misaligned leaves (norms, odd supports) take the deferred path and
    must also agree."""
    steps = 8
    cfg = _smoke_cfg("dense")
    loss_g, _, (pg, sg) = _run_training(cfg, steps, update_mode="global",
                                        opt_name="adam8bit")
    loss_p, _, (pp, sp) = _run_training(cfg, steps, update_mode="per_layer",
                                        opt_name="adam8bit")
    np.testing.assert_allclose(loss_p, loss_g, rtol=0, atol=2e-5)
    # end-state parity: params and quantized optimizer state trees agree
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=1e-4, atol=1e-5), pg, pp)
    assert jax.tree.structure(sg) == jax.tree.structure(sp)


def test_perlayer_fused_kernel_dispatch_matches_global():
    """Under exec_mode="fused", sliced adam8bit updates route through the
    Pallas kernel (the default fused_opt wiring); after the ISSUE-4 scalar
    fix the kernel tracks the XLA reference to ulp, so parity with the
    global (XLA) update must hold."""
    steps = 6
    cfg = _smoke_cfg("fused")
    loss_g, _, _ = _run_training(cfg, steps, update_mode="global",
                                 opt_name="adam8bit")
    loss_p, _, _ = _run_training(cfg, steps, update_mode="per_layer",
                                 opt_name="adam8bit")  # fused_opt defaults on
    np.testing.assert_allclose(loss_p, loss_g, rtol=0, atol=2e-5)


def test_perlayer_moe_dense_prefix_and_aux():
    """MoE coverage: first-k-dense prefix sweeps through the dense stack,
    router aux flows into loss/metrics identically to global mode."""
    steps = 4
    cfg = _smoke_cfg(arch="deepseek_moe_16b")
    loss_g, gn_g, _ = _run_training(cfg, steps, update_mode="global")
    loss_p, gn_p, _ = _run_training(cfg, steps, update_mode="per_layer")
    np.testing.assert_allclose(loss_p, loss_g, rtol=0, atol=3e-5)
    np.testing.assert_allclose(gn_p, gn_g, rtol=2e-5, atol=0)


@pytest.mark.parametrize("opt_name", ["adamw", "adam8bit"])
def test_perlayer_tied_embeddings_fold_head_cotangent(opt_name):
    """Tied configs close the embedding over as a constant in the head
    vjp and recompute the unembed's embed-cotangent at the embed step of
    each pass (instead of carrying a V x d f32 cotangent down the sweep)
    — the fold must still be value-identical to global autodiff
    accumulation: losses AND grad norms track the global step."""
    steps = 3
    cfg = dataclasses.replace(_smoke_cfg("dense"), tie_embeddings=True)
    loss_g, gn_g, _ = _run_training(cfg, steps, update_mode="global",
                                    opt_name=opt_name)
    loss_p, gn_p, _ = _run_training(cfg, steps, update_mode="per_layer",
                                    opt_name=opt_name)
    np.testing.assert_allclose(loss_p, loss_g, rtol=0, atol=2e-5)
    # the grad norm folds the embed cotangent too (norm sweep recompute)
    np.testing.assert_allclose(gn_p, gn_g, rtol=2e-5, atol=0)


def test_perlayer_layer_timing_histogram():
    """With a layer_timing registry the update sweep records one
    observation per layer per step via ordered io_callback — and the
    timing hop must not perturb the math (loss parity vs untimed)."""
    from repro.obs import metrics as obs_metrics

    steps = 2
    cfg = _smoke_cfg("dense")
    api = registry.get_api(cfg)
    opt = optimizers.make(OptimizerConfig(name="adamw", lr=1e-3,
                                          warmup_steps=2, total_steps=steps))
    reg = obs_metrics.Registry()
    runs = {}
    for label, timing in (("untimed", None), ("timed", reg)):
        params, consts = api.init(cfg, jax.random.PRNGKey(42), seed=42)
        opt_state = opt.init(params)
        fn = jax.jit(perlayer.make_perlayer_train_step(
            cfg, api, opt, layer_timing=timing))
        data = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)
        losses = []
        for _ in range(steps):
            batch = {k: jnp.asarray(v)
                     for k, v in data.next_batch().items()}
            params, opt_state, metrics = fn(params, opt_state, consts,
                                            batch)
            losses.append(float(metrics["loss"]))
        runs[label] = losses

    assert runs["timed"] == runs["untimed"]
    h = reg.get("train.perlayer.layer_update_ms")
    jax.effects_barrier()  # drain any in-flight ordered callbacks
    assert h.count == steps * cfg.n_layers, (h.count, cfg.n_layers)
    assert h.sum >= 0


def test_perlayer_galore_runs_and_tracks_global():
    steps = 4
    cfg = _smoke_cfg("dense")
    loss_g, _, _ = _run_training(cfg, steps, update_mode="global",
                                 opt_name="galore_adamw")
    loss_p, _, _ = _run_training(cfg, steps, update_mode="per_layer",
                                 opt_name="galore_adamw")
    np.testing.assert_allclose(loss_p, loss_g, rtol=0, atol=2e-5)


def test_perlayer_rejects_nonlm():
    opt = optimizers.make(OptimizerConfig())
    xl = registry.get_smoke_config("xlstm_350m")
    with pytest.raises(ValueError, match="per-layer"):
        perlayer.make_perlayer_train_step(
            xl, registry.get_api(xl), opt)


@pytest.mark.parametrize("exec_mode", ["dense", "fused"])
def test_perlayer_grad_accum_matches_global_grad_accum(exec_mode):
    """ISSUE 8 acceptance: 20-step per_layer + grad_accum=2 must be
    token-for-token equal to global + grad_accum=2 (dense AND fused) —
    the in-sweep microbatch accumulator reproduces sum-then-divide grads
    and the clip norm of the averaged tree without ever materializing
    the full gradient tree."""
    steps = 20
    cfg = _smoke_cfg(exec_mode)
    api = registry.get_api(cfg)
    opt_cfg = OptimizerConfig(name="adamw", lr=1e-3, warmup_steps=2,
                              total_steps=steps)
    data_g = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)
    data_p = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)

    opt = optimizers.make(opt_cfg)
    fn_g = jax.jit(step_lib.make_train_step(cfg, api, opt, grad_accum=2))
    fn_p = jax.jit(perlayer.make_perlayer_train_step(cfg, api, opt,
                                                     grad_accum=2))
    pg, cg = api.init(cfg, jax.random.PRNGKey(42), seed=42)
    pp, cp = api.init(cfg, jax.random.PRNGKey(42), seed=42)
    sg, sp = opt.init(pg), opt.init(pp)
    loss_g, loss_p, gn_g, gn_p = [], [], [], []
    for _ in range(steps):
        bg = {k: jnp.asarray(v) for k, v in data_g.next_batch().items()}
        bp = {k: jnp.asarray(v) for k, v in data_p.next_batch().items()}
        pg, sg, mg = fn_g(pg, sg, cg, bg)
        pp, sp, mp = fn_p(pp, sp, cp, bp)
        loss_g.append(float(mg["loss"]))
        loss_p.append(float(mp["loss"]))
        gn_g.append(float(mg["grad_norm"]))
        gn_p.append(float(mp["grad_norm"]))
    np.testing.assert_allclose(loss_p, loss_g, rtol=0, atol=2e-5)
    np.testing.assert_allclose(gn_p, gn_g, rtol=2e-5, atol=0)


def test_perlayer_grad_accum_tied_and_moe():
    """grad_accum=2 through the tied-embedding head fold and the MoE
    dense-prefix + router-aux paths (the stacked-cotangent sweeps)."""
    for arch, tie in (("llama_60m", True), ("deepseek_moe_16b", False)):
        cfg = _smoke_cfg(arch=arch)
        if tie:
            cfg = dataclasses.replace(cfg, tie_embeddings=True)
        api = registry.get_api(cfg)
        opt = optimizers.make(OptimizerConfig(name="adamw", lr=1e-3,
                                              warmup_steps=2, total_steps=4))
        fn_g = jax.jit(step_lib.make_train_step(cfg, api, opt, grad_accum=2))
        fn_p = jax.jit(perlayer.make_perlayer_train_step(cfg, api, opt,
                                                         grad_accum=2))
        params, consts = api.init(cfg, jax.random.PRNGKey(1), seed=1)
        st = opt.init(params)
        data = SyntheticC4(cfg.vocab_size, 32, 4, seed=3)
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        _, _, mg = fn_g(params, st, consts, batch)
        _, _, mp = fn_p(params, st, consts, batch)
        np.testing.assert_allclose(float(mp["loss"]), float(mg["loss"]),
                                   rtol=0, atol=3e-5)
        np.testing.assert_allclose(float(mp["grad_norm"]),
                                   float(mg["grad_norm"]), rtol=2e-5)


# ---------------------------------------------------------------------------
# Unit: Optimizer per-layer slice API on stacked params
# ---------------------------------------------------------------------------

def _stacked_tree(key, n=4):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "layers": {
            # per-layer flat size 8*32=256: whole q-blocks -> sliceable
            "w": jax.random.normal(k1, (n, 8, 32), jnp.float32),
            # per-layer size 24: straddles q-blocks -> deferred path
            "b": jax.random.normal(k2, (n, 24), jnp.float32),
        },
        "out": jax.random.normal(k3, (16, 16), jnp.float32),
    }


@pytest.mark.parametrize("name", ["adamw", "adam8bit", "galore_adamw"])
def test_update_slice_api_matches_global_update(name):
    """Driving prepare/stack_state/update_slice/finish by hand — slicing
    layer by layer like the sweep does — must reproduce optimizer.update
    exactly on a stacked tree, for every optimizer."""
    oc = OptimizerConfig(name=name, lr=0.01, warmup_steps=2, total_steps=10,
                         weight_decay=0.01, galore_rank=4)
    opt = optimizers.make(oc)
    params = _stacked_tree(jax.random.PRNGKey(0))
    grads = _stacked_tree(jax.random.PRNGKey(1))
    state = opt.init(params)

    ref_p, ref_s, ref_stats = opt.update(grads, state, params)

    n = 4
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    ctx, stats = opt.prepare(state, gnorm)
    new_p = {"layers": {}, "out": None}
    st = state
    for path, leaf, g in [
            (("layers", "w"), params["layers"]["w"], grads["layers"]["w"]),
            (("layers", "b"), params["layers"]["b"], grads["layers"]["b"]),
            (("out",), params["out"], grads["out"])]:
        ls = opt.leaf_state(st, path)
        stacked = len(path) == 2
        sliced = opt.stack_state(ls, leaf, n) if stacked else None
        if sliced is not None:
            ps, ss = [], []
            for i in range(n):
                ls_i = jax.tree.map(lambda l: l[i], sliced)
                np_, nls = opt.update_slice(ctx, leaf[i], g[i], ls_i,
                                            full_ndim=leaf.ndim)
                ps.append(np_)
                ss.append(nls)
            new_leaf = jnp.stack(ps)
            new_ls = opt.unstack_state(
                jax.tree.map(lambda *xs: jnp.stack(xs), *ss), leaf, n)
        else:
            new_leaf, new_ls = opt.update_slice(ctx, leaf, g, ls)
        st = opt.with_leaf_state(st, path, new_ls)
        if len(path) == 2:
            new_p["layers"][path[1]] = new_leaf
        else:
            new_p["out"] = new_leaf
    st = opt.finish(st, ctx)

    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=1e-6, atol=1e-7), ref_p, new_p)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=1e-6, atol=1e-7), ref_s, st)
    assert float(stats["grad_norm"]) == pytest.approx(
        float(ref_stats["grad_norm"]))


def test_adam8bit_stack_state_alignment_rules():
    oc = OptimizerConfig(name="adam8bit")
    opt = optimizers.make(oc)
    p_ok = jnp.zeros((4, 8, 32))      # 256/layer: aligned
    p_bad = jnp.zeros((4, 24))        # 24/layer: straddles blocks
    st = opt.init({"a": p_ok, "b": p_bad})
    ok = opt.stack_state(opt.leaf_state(st, ("a",)), p_ok, 4)
    assert ok is not None
    assert ok["mu"]["codes"].shape == (4, 1, 256)
    assert opt.stack_state(opt.leaf_state(st, ("b",)), p_bad, 4) is None


# ---------------------------------------------------------------------------
# Memory estimator: Appendix-F gradient + transient residency, the 73%
# ---------------------------------------------------------------------------

def test_training_estimate_perlayer_shrinks_residency():
    cfg = dict(memory.PAPER_LLAMA["7b"])
    rank = cfg.pop("rank")
    inv = memory.llama_inventory(**cfg)
    kw = dict(optimizer="adam8bit", rank=rank, delta=0.05, index_bytes=4)
    g = memory.training_estimate(inv, "sltrain", update_mode="global", **kw)
    p = memory.training_estimate(inv, "sltrain", update_mode="per_layer",
                                 **kw)
    # O(P_trainable) -> O(P_layer-ish): the biggest update group at 7B is
    # the (untied) embedding, ~4% of the trainable count
    assert p.resident_count < 0.05 * g.resident_count
    assert (p.grad_bytes + p.transient_bytes) \
        < 0.05 * (g.grad_bytes + g.transient_bytes)
    # params + optimizer state are residency-invariant (layout-identical)
    assert p.param_bytes == g.param_bytes
    assert p.optim_bytes == g.optim_bytes


def test_memory_reproduces_paper_73_percent_7b():
    """sltrain + adam8bit(fused) + per_layer vs full-rank AdamW on LLaMA 7B
    must reproduce the paper's headline 'up to 73%' memory reduction:
    73.6% with the framework's int32 on-device indices, 71.2% with the
    paper's int64 accounting."""
    r32 = memory.paper_f_reduction("7b", index_bytes=4)
    r64 = memory.paper_f_reduction("7b", index_bytes=8)
    assert r32["reduction"] == pytest.approx(0.736, abs=0.01)
    assert r64["reduction"] == pytest.approx(0.712, abs=0.01)
    assert r32["resident_ratio"] < 0.05


# ---------------------------------------------------------------------------
# Boundary-activation sharding specs
# ---------------------------------------------------------------------------

def test_boundary_save_specs():
    from jax.sharding import PartitionSpec as P

    from repro.dist import sharding as shl

    class _Mesh:  # spec engine only reads axis_names/shape (test_dist idiom)
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    mesh = _Mesh()
    xs = jnp.zeros((8, 32, 64, 512))           # (L, B, S, d)
    spec = shl.boundary_save_specs(xs, mesh)
    assert spec == P(None, ("data",), None, None)
    spec_sp = shl.boundary_save_specs(xs, mesh, seq_sharded=True)
    assert spec_sp == P(None, ("data",), ("model",), None)
    # off-mesh constrain degrades to a no-op
    y = shl.constrain_boundary(jnp.zeros((2, 4, 8)), seq_sharded=True)
    assert y.shape == (2, 4, 8)


# ---------------------------------------------------------------------------
# Satellite: grad-accum metrics keep the true ce/aux split
# ---------------------------------------------------------------------------

def test_grad_accum_metrics_keep_aux_split():
    """The grad_accum > 1 branch used to fabricate aux=0 (parts were
    discarded); with a router-aux MoE config the accumulated metrics must
    carry the true split and match the single-shot step."""
    cfg = _smoke_cfg(arch="deepseek_moe_16b")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    opt = optimizers.make(OptimizerConfig(lr=1e-3, warmup_steps=2,
                                          total_steps=4))
    data = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}

    fn1 = jax.jit(step_lib.make_train_step(cfg, api, opt))
    fn2 = jax.jit(step_lib.make_train_step(cfg, api, opt, grad_accum=2))
    _, _, m1 = fn1(params, opt.init(params), consts, batch)
    _, _, m2 = fn2(params, opt.init(params), consts, batch)

    assert float(m2["aux"]) > 0.0, "MoE router aux vanished under accum"
    # loss decomposes: loss == ce + aux_coef * aux (coef 0.01 default)
    assert float(m2["loss"]) == pytest.approx(
        float(m2["ce"]) + 0.01 * float(m2["aux"]), rel=1e-5)
    # microbatch-averaged split tracks the single-shot split
    assert float(m2["aux"]) == pytest.approx(float(m1["aux"]), rel=0.2)
