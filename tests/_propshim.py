"""Minimal stand-in for ``hypothesis`` on environments without it.

Implements just the surface the test suite uses — ``given``, ``settings``
and the ``strategies`` factories — as a deterministic seeded loop: each
example draws its values from a PRNG keyed on (test name, example index),
so runs are reproducible and failures name a stable example. No
shrinking, no database; for exploratory power install the real
``hypothesis`` (see requirements-dev.txt) — the test modules prefer it
automatically when importable.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*elements):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elements))


def given(**strats):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                seed = zlib.crc32(f"{fn.__module__}.{fn.__name__}:{i}"
                                  .encode())
                rng = np.random.default_rng(seed)
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {i}: "
                        f"{drawn!r}") from e
        # pytest resolves parameters via inspect.signature, which follows
        # __wrapped__ back to fn and would treat the drawn arguments as
        # fixtures; present the zero-arg wrapper signature instead.
        del wrapper.__wrapped__
        wrapper._max_examples = _DEFAULT_MAX_EXAMPLES
        return wrapper
    return decorate


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        if hasattr(fn, "_max_examples"):
            fn._max_examples = max_examples
        return fn
    return decorate
