"""Tests for the paged KV-cache subsystem (serve/kv.py, serve/scheduler.py)
and the rewritten serve engine: allocator lifecycle, jit gather/scatter
roundtrip, batched prefill vs per-token decode equivalence, per-slot decode
positions, and the staggered-arrival regression for the legacy engine's
shared-max(pos) bug."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.serve import kv as kv_lib
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Scheduler, _bucket


@pytest.fixture(scope="module")
def model():
    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    return cfg, api, params, consts


# ---------------------------------------------------------------------------
# Block table / allocator
# ---------------------------------------------------------------------------

def test_block_table_alloc_free_reuse():
    layout = kv_lib.PagedLayout.plan(n_slots=2, max_len=32, block_len=8)
    assert layout.blocks_per_slot == 4 and layout.n_blocks == 9  # + null
    bt = kv_lib.BlockTable(layout, n_slots=2)
    assert bt.free_blocks == 8 and bt.blocks_in_use == 0
    assert bt.ensure(0, 9)                 # 2 blocks
    assert bt.blocks_in_use == 2
    assert (bt.table[0, :2] > 0).all() and (bt.table[0, 2:] == 0).all()
    assert bt.ensure(0, 9)                 # idempotent: no regrow
    assert bt.blocks_in_use == 2
    used = set(bt.table[0, :2].tolist())
    bt.release(0)
    assert bt.blocks_in_use == 0 and (bt.table[0] == 0).all()
    bt.ensure(1, 32)                       # freed blocks are reused
    assert used <= set(bt.table[1].tolist())


def test_block_table_exhaustion_and_overflow():
    layout = kv_lib.PagedLayout.plan(2, 32, 8, n_blocks=3)  # 2 usable
    bt = kv_lib.BlockTable(layout, n_slots=2)
    assert bt.ensure(0, 16)                # both blocks
    assert not bt.ensure(1, 8)             # pool exhausted → backpressure
    assert not bt.can_fit(1)
    bt.release(0)
    assert bt.ensure(1, 8)
    with pytest.raises(ValueError):        # beyond table width
        bt.ensure(1, 33)


def test_block_table_rows_nulls_unlisted_slots():
    layout = kv_lib.PagedLayout.plan(3, 16, 8)
    bt = kv_lib.BlockTable(layout, n_slots=3)
    bt.ensure(0, 16)
    bt.ensure(2, 8)
    rows = bt.rows([2])
    assert (rows[0] == 0).all() and (rows[1] == 0).all()
    assert (rows[2] == bt.table[2]).all()


def test_prefill_bucket_rounds_to_pow2():
    assert _bucket(3, 8) == 8
    assert _bucket(9, 8) == 16
    assert _bucket(16, 8) == 16


# ---------------------------------------------------------------------------
# Device gather / scatter
# ---------------------------------------------------------------------------

def test_scatter_gather_roundtrip_jit():
    layout = kv_lib.PagedLayout.plan(2, 24, 8)
    bt = kv_lib.BlockTable(layout, n_slots=2)
    bt.ensure(0, 24)
    bt.ensure(1, 16)
    pool = jnp.zeros((layout.n_blocks, layout.block_len, 2, 4), jnp.float32)
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.standard_normal((2, 16, 2, 4)), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None],
                                 (2, 16))
    table = bt.as_array()
    scatter = jax.jit(kv_lib.scatter)
    gather = jax.jit(kv_lib.gather_view)
    pool = scatter(pool, table, positions, vals)
    view = gather(pool, table)
    assert view.shape == (2, layout.view_len, 2, 4)
    np.testing.assert_array_equal(np.asarray(view[:, :16]), np.asarray(vals))
    # null block protects unallocated writes: a row with a nulled table
    # never sees another row's data
    null_rows = jnp.zeros_like(table)
    v2 = gather(pool, null_rows)
    expect = np.tile(np.asarray(pool[0]), (layout.blocks_per_slot, 1, 1))
    np.testing.assert_array_equal(np.asarray(v2),
                                  np.broadcast_to(expect[None], v2.shape))


def test_scatter_per_slot_positions_diverge():
    """Each slot writes at its OWN position — the per-slot index fix."""
    layout = kv_lib.PagedLayout.plan(2, 16, 4)
    bt = kv_lib.BlockTable(layout, 2)
    bt.ensure(0, 8)
    bt.ensure(1, 3)
    pool = jnp.zeros((layout.n_blocks, 4, 1, 1), jnp.float32)
    table = bt.as_array()
    pos = jnp.asarray([[7], [2]], jnp.int32)        # diverging positions
    vals = jnp.asarray([[[[1.0]]], [[[2.0]]]])
    view = kv_lib.gather_view(kv_lib.scatter(pool, table, pos, vals), table)
    assert float(view[0, 7, 0, 0]) == 1.0 and float(view[0, 2, 0, 0]) == 0.0
    assert float(view[1, 2, 0, 0]) == 2.0 and float(view[1, 7, 0, 0]) == 0.0


# ---------------------------------------------------------------------------
# Batched prefill == per-token decode (model level)
# ---------------------------------------------------------------------------

def test_paged_prefill_matches_token_by_token_decode(model):
    cfg, api, params, consts = model
    from repro.train import step as step_lib
    toks = np.asarray([[5, 9, 11, 2, 7, 3]], np.int32)
    max_len = 16

    # reference: contiguous cache, one token at a time
    cache = api.init_cache(cfg, 1, max_len)
    for t in range(toks.shape[1]):
        ref_logits, cache = api.decode_step(
            cfg, params, consts, jnp.asarray(toks[:, t:t + 1]), cache,
            jnp.int32(t))

    # paged: one batched prefill writes all K/V and scores the last token
    layout = kv_lib.PagedLayout.plan(1, max_len, 4)
    bt = kv_lib.BlockTable(layout, 1)
    bt.ensure(0, toks.shape[1])
    pcache = api.init_cache(cfg, 1, max_len, paged=True, block_len=4)
    prefill = jax.jit(step_lib.make_prefill_step(cfg, api))
    first, logits, pcache = prefill(params, consts, jnp.asarray(toks), pcache,
                                    jnp.asarray([toks.shape[1]], jnp.int32),
                                    bt.as_array())
    np.testing.assert_allclose(
        np.asarray(logits[:, -1], np.float32),
        np.asarray(ref_logits[:, 0], np.float32), atol=0.02, rtol=0.02)

    # and the caches agree: next decode step produces identical tokens
    serve = jax.jit(step_lib.make_serve_step(cfg, api))
    nxt_ref, _, _ = serve(params, consts, first, cache,
                          jnp.int32(toks.shape[1]))
    nxt_paged, _, _ = serve(params, consts, first, pcache,
                            jnp.asarray([toks.shape[1]], jnp.int32),
                            bt.as_array())
    assert int(nxt_ref[0, 0]) == int(nxt_paged[0, 0])


# ---------------------------------------------------------------------------
# Engine: paged vs legacy, staggered arrivals
# ---------------------------------------------------------------------------

PROMPTS = [[5, 9, 11], [7, 3, 2, 8, 6], [4, 4, 13], [9, 2]]


def _single_run(model, prompt, n_new, paged):
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32, paged=paged)
    r = eng.submit(prompt, max_new_tokens=n_new)
    eng.run_until_drained()
    return r.out


def test_paged_single_request_matches_legacy(model):
    for p in PROMPTS:
        assert _single_run(model, p, 5, True) == \
            _single_run(model, p, 5, False), p


def test_staggered_arrivals_match_single_runs(model):
    """Requests of different prompt lengths submitted across multiple
    step() calls must each decode exactly as if served alone — the
    regression test for the legacy shared-max(pos) K/V write offset (a
    lagging slot's K/V scattered at another slot's position)."""
    cfg, api, params, consts = model
    singles = [_single_run(model, p, 6, True) for p in PROMPTS]
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32, paged=True)
    reqs = [eng.submit(PROMPTS[0], max_new_tokens=6)]
    for p in PROMPTS[1:]:
        eng.step()                      # positions diverge between arrivals
        reqs.append(eng.submit(p, max_new_tokens=6))
    stats = eng.run_until_drained()
    assert [r.out for r in reqs] == singles
    assert all(r.done for r in reqs)
    assert {r.uid for r in stats["completed"]} == {r.uid for r in reqs}
    assert not stats["exhausted"]


def test_run_until_drained_returns_completed(model):
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32, paged=True)
    reqs = [eng.submit(p, max_new_tokens=3) for p in PROMPTS]
    stats = eng.run_until_drained()
    assert sorted(r.uid for r in stats["completed"]) == \
        sorted(r.uid for r in reqs)
    assert all(len(r.out) == 3 for r in stats["completed"])
    assert stats["exhausted"] is False
    assert stats["decode_steps"] == eng._steps


def test_run_until_drained_reports_exhaustion(model):
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=1, max_len=32, paged=True)
    eng.submit([5, 9], max_new_tokens=20)
    eng.submit([7, 3], max_new_tokens=20)
    with pytest.warns(UserWarning, match="max_steps"):
        stats = eng.run_until_drained(max_steps=2)
    assert stats["exhausted"] is True
    assert len(stats["completed"]) == 0


def test_paged_prefill_dispatch_count(model):
    """Batched prefill: one jit dispatch per admission batch, not one per
    prompt token (legacy: sum of prompt lengths)."""
    cfg, api, params, consts = model
    outs = {}
    for paged in (False, True):
        eng = ServeEngine(cfg, params, consts, n_slots=4, max_len=32,
                          paged=paged)
        for p in PROMPTS:
            eng.submit(p, max_new_tokens=4)
        eng.run_until_drained()
        outs[paged] = dict(eng.dispatches)
    assert outs[False]["prefill"] == sum(len(p) for p in PROMPTS)
    assert outs[True]["prefill"] == 1      # all 4 fit the 4 slots → 1 batch


def test_paged_engine_frees_blocks(model):
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32, paged=True,
                      block_len=8)
    for p in PROMPTS:
        eng.submit(p, max_new_tokens=4)
    eng.run_until_drained()
    assert eng.sched.blocks.blocks_in_use == 0


def test_paged_engine_backpressure_tiny_pool(model):
    """An undersized pool serializes requests instead of crashing."""
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32, paged=True,
                      block_len=8, n_blocks=3)     # 2 usable blocks
    reqs = [eng.submit(p, max_new_tokens=4) for p in PROMPTS[:3]]
    stats = eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert [r.out for r in reqs] == \
        [_single_run(model, p, 4, True) for p in PROMPTS[:3]]
    assert not stats["exhausted"]


def test_submit_rejects_bad_prompts_without_wedging(model):
    """Oversized/empty prompts fail at submit(), not from inside step(),
    so a bad request can never strand the queue behind it."""
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=16, paged=True)
    ok = eng.submit(PROMPTS[0], max_new_tokens=3)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(list(range(3, 20)), max_new_tokens=3)
    with pytest.raises(ValueError, match="empty"):
        eng.submit([], max_new_tokens=3)
    stats = eng.run_until_drained()
    assert ok.done and len(ok.out) == 3
    assert not stats["exhausted"]


def test_submit_rejects_prompt_the_pool_cannot_hold(model):
    """A prompt that fits max_len but not the whole block pool would sit
    at the FIFO head forever and starve everything behind it — reject it
    at submit()."""
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=1, max_len=32, paged=True,
                      block_len=8, n_blocks=3)      # 2 usable = 16 tokens
    with pytest.raises(ValueError, match="n_blocks"):
        eng.submit([5] * 20, max_new_tokens=3)
    ok = eng.submit([5] * 10, max_new_tokens=3)     # queued later, unaffected
    stats = eng.run_until_drained()
    assert ok.done and len(ok.out) == 3
    assert not stats["exhausted"]


def test_all_parked_pool_preempts_and_recovers(model):
    """When every active slot is parked for blocks, the engine preempts
    the youngest request (recompute on readmission) instead of spinning —
    outputs still match single-request runs."""
    cfg, api, params, consts = model
    long_prompts = [[3 + i] * 15 for i in range(2)]
    singles = [_single_run(model, p, 12, True) for p in long_prompts]
    # 7 usable blocks of 8: both 15-token prompts admit (2 blocks each)
    # but cannot both grow to 15 + 12 tokens (4 blocks each)
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32, paged=True,
                      block_len=8, n_blocks=8)
    reqs = [eng.submit(p, max_new_tokens=12) for p in long_prompts]
    stats = eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert [r.out for r in reqs] == singles
    assert not stats["exhausted"]
    assert eng.sched.blocks.blocks_in_use == 0


def test_lone_request_pool_too_small_raises(model):
    """A pool that cannot hold even one request's working set fails loudly
    instead of livelocking."""
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=1, max_len=32, paged=True,
                      block_len=8, n_blocks=3)      # 2 usable blocks
    eng.submit([5] * 15, max_new_tokens=10)         # needs 3 blocks by t=17
    with pytest.raises(RuntimeError, match="n_blocks"):
        eng.run_until_drained()


def test_prefill_bucket_capped_at_view_len(model):
    """A prompt whose power-of-two bucket exceeds view_len must not pad
    past the block-table width (max_len=48 → view 48, prompt 33 → bucket
    64 uncapped): outputs match a plain single-request run."""
    cfg, api, params, consts = model
    prompt = [3 + (i % 40) for i in range(33)]
    outs = {}
    for paged in (False, True):
        eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=48,
                          paged=paged, block_len=16)
        r = eng.submit(prompt, max_new_tokens=6)
        eng.run_until_drained()
        outs[paged] = r.out
    assert outs[True] == outs[False]


def test_paged_sparse_decode_matches_dense(model):
    """exec_mode=sparse on the paged path emits identical tokens."""
    cfg, api, params, consts = model
    outs = []
    for sparse in (False, True):
        eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32,
                          paged=True, sparse_decode=sparse)
        reqs = [eng.submit(p, max_new_tokens=5) for p in PROMPTS[:2]]
        eng.run_until_drained()
        outs.append([r.out for r in reqs])
    assert outs[0] == outs[1]
