"""Parity tests for the Pallas paged-attention decode kernel
(kernels/paged_attention.py): kernel vs the pure-jnp oracle and vs the
gather_view+dense decode path across staggered per-slot positions, partial
tail blocks, block lengths, GQA and idle/null-block slots; a multi-step
greedy-decode engine test with ``attn_kernel="paged"``; and the
poisoned-null-block regression (NaN garbage in unallocated pages must not
leak into either attention path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ShardingConfig
from repro.dist import sharding as shl
from repro.kernels import ops, ref
from repro.models import registry
from repro.serve import kv as kv_lib
from repro.serve.engine import ServeEngine


# ---------------------------------------------------------------------------
# Kernel vs oracle vs gathered-view dense attention
# ---------------------------------------------------------------------------

def _mk_case(rng, *, n_slots, block_len, bps, n_kv, n_heads, hd, positions):
    """Random pools + a block table covering each slot's positions.
    ``positions[s] < 0`` marks slot s idle: all-null table row, position 0
    (exactly how the scheduler parks an empty slot)."""
    n_blocks = 1 + n_slots * bps
    k_pool = jnp.asarray(rng.standard_normal((n_blocks, block_len, n_kv, hd)),
                         jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((n_blocks, block_len, n_kv, hd)),
                         jnp.float32)
    table = np.zeros((n_slots, bps), np.int32)
    nid = 1
    pos = np.zeros(n_slots, np.int32)
    for s, p in enumerate(positions):
        if p < 0:
            continue                     # idle slot
        pos[s] = p
        for j in range(kv_lib.blocks_for(p + 1, block_len)):
            table[s, j] = nid
            nid += 1
    q = jnp.asarray(rng.standard_normal((n_slots, n_heads, hd)), jnp.float32)
    return q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(pos)


def _gather_dense(q, k_pool, v_pool, table, positions, *, scale,
                  softcap=0.0, window=0):
    """The production gather path in miniature: gather_view + the
    null-row zeroing from models/attention + dense masked softmax."""
    n_slots, n_heads, hd = q.shape
    bl, n_kv = k_pool.shape[1], k_pool.shape[2]
    g = n_heads // n_kv
    k = kv_lib.gather_view(k_pool, table).astype(jnp.float32)
    v = kv_lib.gather_view(v_pool, table).astype(jnp.float32)
    live = jnp.repeat(table != 0, bl, axis=1)
    k = jnp.where(live[:, :, None, None], k, 0)
    v = jnp.where(live[:, :, None, None], v, 0)
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    qg = q.reshape(n_slots, n_kv, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("shgd,slhd->shgl", qg, k)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    mask = kpos[None, :] <= positions[:, None]
    if window > 0:
        mask &= (positions[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("shgl,shld->shgd", p,
                      v.swapaxes(1, 2)).reshape(n_slots, n_heads, hd)


CASES = [
    # (block_len, n_kv, n_heads, hd, positions) — staggered, partial
    # tails, idle slots (-1), GQA (n_kv < n_heads) and MHA
    (8, 2, 4, 16, [19, 7, 5, -1]),
    (8, 4, 4, 8, [0, 8, 23, 15]),
    (16, 2, 8, 8, [1, 30, 16, -1]),
    (16, 1, 4, 16, [31, 2, -1, 12]),
    (32, 2, 4, 8, [33, 63, 0, 31]),
]


@pytest.mark.parametrize("block_len,n_kv,n_heads,hd,positions", CASES)
def test_kernel_matches_ref_and_gather(block_len, n_kv, n_heads, hd,
                                       positions):
    rng = np.random.default_rng(hash((block_len, n_kv)) % 2**31)
    bps = kv_lib.blocks_for(max(positions) + 1, block_len)
    q, kp, vp, table, pos = _mk_case(
        rng, n_slots=len(positions), block_len=block_len, bps=bps,
        n_kv=n_kv, n_heads=n_heads, hd=hd, positions=positions)
    scale = hd ** -0.5
    out = ops.paged_attention(q, kp, vp, table, pos, scale=scale)
    oracle = ref.paged_attention_ref(
        q.reshape(q.shape[0], n_kv, n_heads // n_kv, hd), kp, vp, table,
        pos, scale=scale).reshape(q.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=1e-5, rtol=1e-5)
    dense = _gather_dense(q, kp, vp, table, pos, scale=scale)
    active = [s for s, p in enumerate(positions) if p >= 0]
    np.testing.assert_allclose(np.asarray(out)[active],
                               np.asarray(dense)[active],
                               atol=1e-5, rtol=1e-5)
    # idle slots: the kernel pins exact zeros (nothing valid to attend)
    for s, p in enumerate(positions):
        if p < 0:
            assert float(jnp.abs(out[s]).max()) == 0.0


@pytest.mark.parametrize("softcap,window", [(30.0, 0), (0.0, 6), (8.0, 12)])
def test_kernel_softcap_and_window(softcap, window):
    """gemma2-style logit softcap and sliding window, in-kernel."""
    rng = np.random.default_rng(7)
    q, kp, vp, table, pos = _mk_case(
        rng, n_slots=3, block_len=8, bps=4, n_kv=2, n_heads=4, hd=8,
        positions=[20, 9, 31])
    out = ops.paged_attention(q, kp, vp, table, pos, scale=8 ** -0.5,
                              softcap=softcap, window=window)
    dense = _gather_dense(q, kp, vp, table, pos, scale=8 ** -0.5,
                          softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               atol=1e-5, rtol=1e-5)


def test_poisoned_null_block_cannot_leak_kernel_level():
    """kv.gather_view's docstring says callers mask by per-slot length —
    but a masked softmax weight is 0 and 0 · NaN = NaN, so garbage in the
    null block could still poison the output through the p @ v matmul.
    Both read paths must be immune by construction (zeroed v rows)."""
    rng = np.random.default_rng(3)
    q, kp, vp, table, pos = _mk_case(
        rng, n_slots=3, block_len=8, bps=3, n_kv=2, n_heads=4, hd=8,
        positions=[12, 3, -1])
    clean_k = ops.paged_attention(q, kp, vp, table, pos, scale=8 ** -0.5)
    clean_d = _gather_dense(q, kp, vp, table, pos, scale=8 ** -0.5)
    kp = kp.at[0].set(jnp.nan)          # poison the null block
    vp = vp.at[0].set(jnp.nan)
    out_k = ops.paged_attention(q, kp, vp, table, pos, scale=8 ** -0.5)
    out_d = _gather_dense(q, kp, vp, table, pos, scale=8 ** -0.5)
    assert np.isfinite(np.asarray(out_k)).all()
    assert np.isfinite(np.asarray(out_d)).all()
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(clean_k))
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(clean_d))


# ---------------------------------------------------------------------------
# Model level: decode_step routes through the kernel
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gqa_model():
    """Tiny GQA (Hkv < H) llama so the kernel's head-group broadcast is
    exercised end-to-end (the llama_60m smoke config is MHA)."""
    cfg = ModelConfig(name="paged-gqa", family="llama", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=512, vocab_pad_multiple=64, max_seq_len=64,
                      tie_embeddings=False)
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(1), seed=1)
    return cfg, api, params, consts


def test_decode_step_kernel_matches_gather(gqa_model):
    """Same cache state, same tokens: logits from attn_kernel='paged' and
    'gather' agree to f32-attention tolerance (model runs bf16)."""
    cfg, api, params, consts = gqa_model
    max_len, bl = 32, 8
    layout = kv_lib.PagedLayout.plan(2, max_len, bl)
    bt = kv_lib.BlockTable(layout, 2)
    bt.ensure(0, 7)
    bt.ensure(1, 3)
    cache = api.init_cache(cfg, 2, max_len, paged=True, block_len=bl)
    rng = np.random.default_rng(0)
    # warm the caches at staggered positions through the gather path
    pos = np.array([0, 0], np.int32)
    for t in range(6):
        toks = jnp.asarray(rng.integers(3, 400, size=(2, 1)), jnp.int32)
        active = [0] if t >= 2 else [0, 1]   # slot 1 lags (staggered)
        step_pos = jnp.asarray(pos, jnp.int32)
        _, cache = api.decode_step(cfg, params, consts, toks, cache,
                                   step_pos, block_table=bt.as_array())
        for s in active:
            pos[s] += 1
    toks = jnp.asarray([[11], [42]], jnp.int32)
    outs = {}
    for ak in ("gather", "paged"):
        c = dataclasses.replace(cfg, attn_kernel=ak)
        logits, _ = api.decode_step(c, params, consts, toks, cache,
                                    jnp.asarray(pos, jnp.int32),
                                    block_table=bt.as_array())
        outs[ak] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["paged"], outs["gather"],
                               atol=0.02, rtol=0.02)
    assert (outs["paged"].argmax(-1) == outs["gather"].argmax(-1)).all()


def test_engine_greedy_decode_token_for_token(gqa_model):
    """Multi-step greedy decode with attn_kernel='paged': staggered
    arrivals, mixed prompt lengths, GQA — token-for-token vs the gather
    path AND vs single-request ground truth."""
    cfg, api, params, consts = gqa_model
    prompts = [[5, 9, 11], [7, 3, 2, 8, 6], [4, 4, 13], [9, 2]]

    def run(ak, stagger=True):
        eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32,
                          paged=True, block_len=8, attn_kernel=ak)
        reqs = [eng.submit(prompts[0], max_new_tokens=6)]
        for p in prompts[1:]:
            if stagger:
                eng.step()
            reqs.append(eng.submit(p, max_new_tokens=6))
        stats = eng.run_until_drained()
        assert not stats["exhausted"]
        return [r.out for r in reqs]

    singles = []
    for p in prompts:
        eng = ServeEngine(cfg, params, consts, n_slots=1, max_len=32,
                          paged=True, block_len=8, attn_kernel="paged")
        r = eng.submit(p, max_new_tokens=6)
        eng.run_until_drained()
        singles.append(r.out)
    out_paged = run("paged")
    assert out_paged == run("gather")
    assert out_paged == singles


def test_engine_poisoned_null_block(gqa_model):
    """End-to-end regression for the kv.gather_view masking promise: NaN
    garbage planted in every layer's null block changes NOTHING on either
    decode path."""
    cfg, api, params, consts = gqa_model
    prompts = [[5, 9, 11], [7, 3, 2, 8]]
    outs = {}
    for ak in ("gather", "paged"):
        for poison in (False, True):
            eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=32,
                              paged=True, block_len=8, attn_kernel=ak)
            if poison:
                eng.cache = jax.tree.map(
                    lambda a: a.at[:, 0].set(jnp.nan), eng.cache)
            reqs = [eng.submit(p, max_new_tokens=5) for p in prompts]
            eng.run_until_drained()
            outs[(ak, poison)] = [r.out for r in reqs]
        assert outs[(ak, True)] == outs[(ak, False)], ak
    assert outs[("paged", False)] == outs[("gather", False)]


def test_engine_rejects_kernel_without_paged_cache(gqa_model):
    cfg, api, params, consts = gqa_model
    with pytest.raises(ValueError, match="paged=True"):
        ServeEngine(cfg, params, consts, paged=False, attn_kernel="paged")
    with pytest.raises(ValueError, match="attn_kernel"):
        ServeEngine(cfg, params, consts, paged=True, attn_kernel="flash")


# ---------------------------------------------------------------------------
# Sharding: the kernel shares the gather path's TP cache layout
# ---------------------------------------------------------------------------

def test_cache_specs_kernel_matches_gather_layout(gqa_model):
    """Toggling attn_kernel must never reshard the pools: both paths use
    the heads-over-model TP layout, blocks replicated."""
    cfg, api, params, consts = gqa_model
    mesh = shl.make_local_mesh()
    cache = api.init_cache(cfg, 2, 32, abstract=True, paged=True, block_len=8)
    s_gather = shl.cache_specs(cache, mesh, paged=True, attn_kernel="gather")
    s_paged = shl.cache_specs(cache, mesh, paged=True, attn_kernel="paged")
    assert s_gather == s_paged
    leaf = jax.tree.leaves(s_paged, is_leaf=lambda x: hasattr(x, "index"))[0]
    assert leaf[-2:] == (("model",), None)   # heads sharded, hd replicated
    assert leaf[-4:-2] == (None, None)       # block dims replicated


def test_cache_specs_kernel_rejects_seq_sharding(gqa_model):
    cfg, api, params, consts = gqa_model
    mesh = shl.make_local_mesh()
    cache = api.init_cache(cfg, 2, 32, abstract=True, paged=True, block_len=8)
    with pytest.raises(ValueError, match="seq-sharded"):
        shl.cache_specs(cache, mesh, paged=True, seq_sharded=True,
                        attn_kernel="paged")
    # the gather path still accepts the flag (paged layout ignores it)
    shl.cache_specs(cache, mesh, paged=True, seq_sharded=True,
                    attn_kernel="gather")


# ---------------------------------------------------------------------------
# Config validation (per_layer × grad_accum composes since the in-sweep
# accumulator landed — repro.train.perlayer)
# ---------------------------------------------------------------------------

def test_sharding_config_accepts_perlayer_grad_accum():
    ShardingConfig(update_mode="per_layer", grad_accum=2)   # in-sweep accum
    ShardingConfig(update_mode="per_layer", grad_accum=1)
    ShardingConfig(update_mode="global", grad_accum=4)
