"""Property-based tests on the paper's core invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pinned env ships no hypothesis: seeded-loop shim
    from _propshim import given, settings, strategies as st

from repro.core import sltrain, support

DIMS = st.integers(min_value=8, max_value=96)


# ---------------------------------------------------------------------------
# Support invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(d_in=DIMS, d_out=DIMS, seed=st.integers(0, 2**31 - 1),
       delta=st.floats(0.01, 0.2),
       kind=st.sampled_from(["row_balanced", "iid"]))
def test_support_valid_and_deterministic(d_in, d_out, seed, delta, kind):
    r1, c1 = support.sample_support(seed, d_in, d_out, delta, kind)
    r2, c2 = support.sample_support(seed, d_in, d_out, delta, kind)
    assert (r1 == r2).all() and (c1 == c2).all()  # restart-safe (DESIGN §7)
    assert r1.shape == c1.shape
    assert (0 <= r1).all() and (r1 < d_in).all()
    assert (0 <= c1).all() and (c1 < d_out).all()
    assert r1.shape[0] == support.nnz_for(d_in, d_out, delta, kind)
    # no duplicate (row, col) pairs — V entries map 1:1 to matrix cells
    flat = r1.astype(np.int64) * d_out + c1
    assert len(np.unique(flat)) == flat.shape[0]


@settings(max_examples=10, deadline=None)
@given(n=st.integers(32, 128), seed=st.integers(0, 1000))
def test_prop1_full_rank_whp(n, seed):
    """Proposition 1: BA + S with random support δ=Ω(log n/n) is full rank."""
    delta = 3.0 * np.log(n) / n
    rng = np.random.default_rng(seed)
    rows, cols = support.sample_support(seed, n, n, delta, "row_balanced")
    S = np.zeros((n, n))
    S[rows, cols] = rng.standard_normal(rows.shape[0])
    B = rng.standard_normal((n, 4))
    A = rng.standard_normal((4, n))
    assert np.linalg.matrix_rank(B @ A + S) == n


def test_lowrank_alone_is_rank_deficient():
    """Counterpoint to Prop. 1: without S the rank is capped at r."""
    rng = np.random.default_rng(0)
    B = rng.standard_normal((64, 4))
    A = rng.standard_normal((4, 64))
    assert np.linalg.matrix_rank(B @ A) == 4


@settings(max_examples=10, deadline=None)
@given(d_in=st.integers(16, 64), d_out=st.integers(16, 64),
       delta=st.floats(0.02, 0.1))
def test_param_count_formula(d_in, d_out, delta):
    """Paper §3.2: params = (d+p)·r + nnz(S)."""
    r = 4
    params, consts = sltrain.init_params(
        jax.random.PRNGKey(0), d_in, d_out, r, delta, jnp.float32)
    trainable = sum(x.size for x in jax.tree.leaves(params))
    expect, nnz = sltrain.param_count(d_in, d_out, r, delta)
    assert trainable == expect
    assert consts["cols"].size == nnz


# ---------------------------------------------------------------------------
# Forward/backward algebra (paper eq. 2)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000),
       kind=st.sampled_from(["row_balanced", "iid"]))
def test_matmul_equals_densified(seed, kind):
    d_in, d_out, r, m = 40, 56, 4, 12
    params, consts = sltrain.init_params(
        jax.random.PRNGKey(seed), d_in, d_out, r, 0.05, jnp.float32, kind,
        seed=seed)
    # non-zero B so the low-rank part contributes
    params["B"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                    params["B"].shape) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (m, d_in))
    y = sltrain.sl_matmul(x, params, consts, 0.5)
    W = sltrain.materialize(params, consts, 0.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ W), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000),
       kind=st.sampled_from(["row_balanced", "iid"]))
def test_custom_vjp_matches_autodiff_of_densified(seed, kind):
    """Gradients from the paper's eq. (2) == autodiff through densify."""
    d_in, d_out, r, m = 32, 48, 4, 10
    params, consts = sltrain.init_params(
        jax.random.PRNGKey(seed), d_in, d_out, r, 0.05, jnp.float32, kind,
        seed=seed)
    params["B"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                    params["B"].shape) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (m, d_in))
    t = jax.random.normal(jax.random.PRNGKey(seed + 3), (m, d_out))

    def loss_fast(p, xx):
        return jnp.sum((sltrain.sl_matmul(xx, p, consts, 0.5) - t) ** 2)

    def loss_ref(p, xx):
        W = sltrain.materialize(p, consts, 0.5)
        return jnp.sum((xx @ W - t) ** 2)

    g1, gx1 = jax.grad(loss_fast, argnums=(0, 1))(params, x)
    g2, gx2 = jax.grad(loss_ref, argnums=(0, 1))(params, x)
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_sparse_exec_mode_matches_dense(seed):
    """Decode path (beyond-paper, DESIGN §3): factored sparse execution must
    agree with the densify path bit-for-bit-ish."""
    d_in, d_out, r = 48, 64, 8
    params, consts = sltrain.init_params(
        jax.random.PRNGKey(seed), d_in, d_out, r, 0.05, jnp.float32,
        seed=seed)
    params["B"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                    params["B"].shape) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (3, d_in))
    y_d = sltrain.sl_matmul(x, params, consts, 0.5, exec_mode="dense")
    y_s = sltrain.sl_matmul(x, params, consts, 0.5, exec_mode="sparse")
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_s), atol=1e-4)


def test_residual_memory_is_factored():
    """Alg. 1: the VJP must save only {x, B, A, v, cols} — the densified W
    must NOT be a residual (that is the paper's memory claim)."""
    d_in, d_out, r, m = 64, 64, 8, 16
    params, consts = sltrain.init_params(
        jax.random.PRNGKey(0), d_in, d_out, r, 0.03, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, d_in))

    def f(p):
        return jnp.sum(sltrain.sl_matmul(x, p, consts, 0.5))

    # linearize exposes the residual pytree sizes
    _, vjp = jax.vjp(f, params)
    leaves = jax.tree.leaves(jax.tree.map(lambda a: a, vjp))
    res_bytes = sum(x.size * x.dtype.itemsize for x in leaves)
    dense_W_bytes = d_in * d_out * 4
    factored = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    # no residual may have W's (d_in, d_out) shape — the densified matrix
    # must stay a transient (the paper's memory claim)
    assert not any(l.shape == (d_in, d_out) and l.dtype.itemsize >= 2
                   for l in leaves), "densified W saved as a residual"
    # residuals ≈ params + x (x appears twice: once as the custom-vjp
    # residual aliasing the input, once as jax.vjp's closure const copy),
    # far below storing W per token-batch
    assert res_bytes <= factored + 2 * x.size * 4 + 4096, \
        f"residuals {res_bytes}B suggest densified W was saved"


# ---------------------------------------------------------------------------
# Tile layout / partition invariants (kernel + TP substrate)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), d_in=st.integers(64, 300),
       d_out=st.integers(64, 300))
def test_tile_layout_is_permutation(seed, d_in, d_out):
    rows, cols = support.sample_support(seed, d_in, d_out, 0.03,
                                        "row_balanced")
    kp = ((d_in + 127) // 128) * 128
    np_ = ((d_out + 127) // 128) * 128
    perm, local, counts, pad = support.tile_layout(rows, cols, kp, np_)
    valid = perm[perm >= 0]
    assert len(np.unique(valid)) == rows.shape[0]  # every entry exactly once
    assert counts.sum() == rows.shape[0]
    # local ids reconstruct global ids
    nt_c = np_ // 128
    for t in range(0, counts.size, max(1, counts.size // 7)):
        tr, tc = t // nt_c, t % nt_c
        sl = slice(t * pad, (t + 1) * pad)
        p = perm[sl]
        loc = local[sl]
        m = p >= 0
        assert (rows[p[m]] == loc[m, 0] + tr * 128).all()
        assert (cols[p[m]] == loc[m, 1] + tc * 128).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), d_in=st.integers(64, 300),
       d_out=st.integers(64, 300), delta=st.floats(0.02, 0.08),
       kind=st.sampled_from(["row_balanced", "iid"]))
def test_tile_layout_roundtrips_through_prepare_tiles(seed, d_in, d_out,
                                                      delta, kind):
    """tile_layout round-trip invariants at the deterministic tile_cap
    capacity: every support entry appears exactly once across tiles,
    padding slots carry perm == -1 and contribute exactly zero through
    prepare_tiles (their baked v is 0)."""
    from repro.kernels import ops
    rows, cols = support.sample_support(seed, d_in, d_out, delta, kind)
    nnz = rows.shape[0]
    rng = np.random.default_rng(seed)
    # strictly nonzero values so a zero in v_t can only mean padding
    v = (rng.random(nnz) + 0.5).astype(np.float32)
    cap = support.tile_cap(d_in, d_out, delta, kind)
    v_t, r_t, c_t, perm = ops.prepare_tiles(rows, cols, v, d_in, d_out,
                                            pad=cap)
    assert v_t.shape == r_t.shape == c_t.shape == perm.shape
    assert v_t.shape[-1] == cap
    p = np.asarray(perm).reshape(-1)
    valid = p[p >= 0]
    # every entry exactly once, indices within the COO arrays
    assert valid.size == nnz
    assert len(np.unique(valid)) == nnz
    assert valid.min() >= 0 and valid.max() < nnz
    # round trip: tile values map back to the original v
    vt_flat = np.asarray(v_t).reshape(-1)
    np.testing.assert_array_equal(vt_flat[p >= 0][np.argsort(valid)],
                                  v[np.sort(valid)])
    # padding slots contribute zero (and sit at harmless local (0, 0))
    assert (vt_flat[p < 0] == 0.0).all()
    loc = np.stack([np.asarray(r_t).reshape(-1),
                    np.asarray(c_t).reshape(-1)], axis=1)
    assert (loc[p < 0] == 0).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000),
       kind=st.sampled_from(["row_balanced", "iid"]))
def test_fused_exec_mode_matches_dense(seed, kind):
    """exec_mode='fused' (Pallas tile kernels through the flat-v gather)
    must agree with the densify path for both support layouts."""
    d_in, d_out, r = 72, 150, 8
    params, consts = sltrain.init_params(
        jax.random.PRNGKey(seed), d_in, d_out, r, 0.05, jnp.float32, kind,
        seed=seed, exec_mode="fused")
    params["B"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                    params["B"].shape) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (2, 5, d_in))
    y_d = sltrain.sl_matmul(x, params, consts, 0.5, exec_mode="dense")
    y_f = sltrain.sl_matmul(x, params, consts, 0.5, exec_mode="fused")
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_d), atol=1e-5,
                               rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), n_shards=st.sampled_from([2, 4, 8]))
def test_partition_support_covers_all(seed, n_shards):
    d_in, d_out = 128, 256
    rows, cols = support.sample_support(seed, d_in, d_out, 0.05,
                                        "row_balanced")
    r, c, m, cap = support.partition_support(rows, cols, n_shards, d_out,
                                             axis="col")
    assert int(m.sum()) == rows.shape[0]
    shard_sz = d_out // n_shards
    for s in range(n_shards):
        sel = m[s]
        assert (c[s][sel] < shard_sz).all()      # indices are shard-local
