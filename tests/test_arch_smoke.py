"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned architecture, run one forward pass (train path) and one decode
step on CPU, assert output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPE_CELLS
from repro.launch import specs
from repro.models import registry

ALL_ARCHS = registry.ARCHS + registry.PAPER_ARCHS[:1]  # 10 assigned + llama-60m


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _build(arch, rng):
    cfg = registry.get_smoke_config(arch)
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, rng, seed=7)
    return cfg, api, params, consts


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg, api, params, consts = _build(arch, rng)
    bsz, seq = 2, 64
    batch = specs.input_specs(cfg, bsz, seq, abstract=False, key=rng)
    logits, aux = jax.jit(
        lambda p, c, b: api.apply(cfg, p, c, b))(params, consts, batch)
    assert logits.shape == (bsz, seq, cfg.padded_vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), f"{arch}: NaN/inf logits"
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step(arch, rng):
    cfg, api, params, consts = _build(arch, rng)
    bsz, max_len = 2, 32
    cache = api.init_cache(cfg, bsz, max_len)
    if cfg.family == "whisper":
        from repro.models import whisper
        frames = specs.input_specs(cfg, bsz, 8, abstract=False, key=rng)["frames"]
        cache = whisper.whisper_prefill_cache(cfg, params, consts, frames,
                                              bsz, max_len)
    tokens, index = specs.decode_inputs(cfg, bsz, 4, abstract=False, key=rng)
    step = jax.jit(lambda p, c, t, kv, i: api.decode_step(cfg, p, c, t, kv, i))
    logits, new_cache = step(params, consts, tokens, cache, jnp.int32(3))
    assert logits.shape == (bsz, 1, cfg.padded_vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), f"{arch}: NaN decode"
    # cache must be structurally unchanged (functional update)
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["yi_34b", "zamba2_7b", "xlstm_350m"])
def test_train_prefix_decode_consistency(arch, rng):
    """Decoding token-by-token must match the teacher-forced forward."""
    cfg, api, params, consts = _build(arch, rng)
    bsz, seq = 1, 8
    batch = specs.input_specs(cfg, bsz, seq, abstract=False, key=rng)
    full_logits, _ = api.apply(cfg, params, consts, batch)
    cache = api.init_cache(cfg, bsz, seq)
    toks = batch["tokens"]
    outs = []
    for t in range(seq):
        logits, cache = api.decode_step(cfg, params, consts, toks[:, t:t + 1],
                                        cache, jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    ref = full_logits.astype(jnp.float32)
    assert jnp.allclose(dec, ref, atol=0.05, rtol=0.05), \
        f"{arch}: decode diverges from teacher forcing " \
        f"(max abs {float(jnp.abs(dec - ref).max()):.4f})"


@pytest.mark.parametrize("arch", ["gemma2_2b", "qwen2_5_32b",
                                  "deepseek_moe_16b"])
def test_decode_consistency_extended(arch, rng):
    """Teacher-forcing vs token-by-token decode for archs with non-vanilla
    attention features: gemma2 softcaps+sliding window, qwen2.5 qkv-bias,
    deepseek shared-expert MoE. Excluded by design: qwen3-moe smoke (top-8
    of 8 experts — capacity-based dispatch drops tokens under batch routing
    but never in single-token decode, a semantic difference of Switch-style
    MoE, not a bug) and paligemma (teacher-forcing substitutes patch
    embeddings that token-only decode cannot reproduce)."""
    cfg, api, params, consts = _build(arch, rng)
    bsz, seq = 1, 8
    batch = specs.input_specs(cfg, bsz, seq, abstract=False, key=rng)
    full_logits, _ = api.apply(cfg, params, consts, batch)
    cache = api.init_cache(cfg, bsz, seq)
    toks = batch["tokens"]
    outs = []
    for t in range(seq):
        logits, cache = api.decode_step(cfg, params, consts,
                                        toks[:, t:t + 1], cache, jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    ref = full_logits.astype(jnp.float32)
    if cfg.family == "vlm":
        # the VLM train path substitutes patch embeddings for the first
        # n_patches positions; decode sees tokens — compare the text tail
        n = min(cfg.n_patches, seq - 1)
        dec, ref = dec[:, n:], ref[:, n:]
    assert jnp.allclose(dec, ref, atol=0.06, rtol=0.06), \
        f"{arch}: decode diverges (max {float(jnp.abs(dec - ref).max()):.4f})"
