"""Optimizer tests: AdamW reference behaviour, 8-bit quantization bounds,
GaLore projection shapes + memory claim, schedules, ReLoRA merge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pinned env ships no hypothesis: seeded-loop shim
    from _propshim import given, settings, strategies as st

from repro.configs.base import OptimizerConfig
from repro.core import relora
from repro.optim import optimizers, quant
from repro.optim.schedule import warmup_cosine


def _quad_params():
    return {"w": jnp.zeros((8, 8)), "b": jnp.zeros(8)}


def _run(opt, steps=80):
    params = _quad_params()
    target = jax.random.normal(jax.random.PRNGKey(0), (8, 8))

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)

    if steps == 0:
        return float(loss(params))
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.grad(loss)(params)
        params, state, stats = opt.update(grads, state, params)
    return float(loss(params))


@pytest.mark.parametrize("name", ["adamw", "adam8bit"])
def test_optimizers_minimize_quadratic(name):
    oc = OptimizerConfig(name=name, lr=0.05, warmup_steps=5, total_steps=80,
                         weight_decay=0.0)
    final = _run(optimizers.make(oc))
    assert final < 1.0, f"{name} failed to optimize: {final}"


def test_galore_minimizes_within_projected_subspace():
    """GaLore with a fixed rank-r projection can only descend inside the
    projected subspace between refreshes — assert substantial progress, not
    full convergence (the projection gap refreshes every 200 steps, beyond
    this test's horizon)."""
    oc = OptimizerConfig(name="galore_adamw", lr=0.05, warmup_steps=5,
                         total_steps=80, weight_decay=0.0, galore_rank=4)
    initial = _run(optimizers.make(oc), steps=0)
    final = _run(optimizers.make(oc))
    assert final < 0.6 * initial, (initial, final)


def test_grad_clip_bounds_update():
    oc = OptimizerConfig(lr=1.0, grad_clip=1e-3, warmup_steps=1,
                         total_steps=10)
    opt = optimizers.make(oc)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    new_params, _, stats = opt.update({"w": jnp.full(4, 1e6)}, state, params)
    assert float(stats["grad_norm"]) > 1e5
    assert float(jnp.abs(new_params["w"]).max()) < 2.0  # clipped step


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), block=st.sampled_from([64, 256]),
       signed=st.booleans())
def test_blockwise_quant_error_bound(seed, block, signed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.abs(rng.standard_normal(1000)) if not signed
                    else rng.standard_normal(1000), jnp.float32)
    codes, scales, n = quant.quantize_blockwise(x, block, signed)
    y = quant.dequantize_blockwise(codes, scales, n, x.shape, signed)
    # per-block error ≤ half a quantization step
    xpad = jnp.pad(x, (0, (-1000) % block)).reshape(-1, block)
    step = (jnp.max(jnp.abs(xpad), axis=1) / 127.0 if signed
            else jnp.max(xpad, axis=1) / 255.0)
    err = jnp.abs(y - x).reshape(-1)
    bound = jnp.repeat(step, block)[:1000] * 0.5 + 1e-7
    assert bool((err <= bound + 1e-6).all())


def test_galore_state_is_low_rank():
    """GaLore's memory claim: moments live in (r × dim), not (dim × dim)."""
    oc = OptimizerConfig(name="galore_adamw", galore_rank=4, lr=0.01,
                         warmup_steps=1, total_steps=10)
    opt = optimizers.make(oc)
    params = {"w": jnp.zeros((64, 128))}
    st_ = opt.init(params)
    leaf = st_["leaves"]["w"]
    assert leaf["mu"].shape == (4, 128)
    assert leaf["P"].shape == (64, 4)
    full = 64 * 128
    got = leaf["mu"].size + leaf["nu"].size + leaf["P"].size
    assert got < 2 * full  # less than plain Adam's 2x


def test_warmup_cosine_schedule():
    oc = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                         min_lr_ratio=0.1)
    lr = warmup_cosine(oc)
    assert float(lr(jnp.int32(0))) < 0.2
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0, rel=0.1)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, rel=0.01)


def test_relora_merge_preserves_function():
    """Merging BA into W0 must not change the layer's function."""
    params = relora.init_params(jax.random.PRNGKey(0), 16, 24, 4)
    params["B"] = jax.random.normal(jax.random.PRNGKey(1), (16, 4)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 16))
    y1 = relora.rl_matmul(x, params, 0.5)
    merged = relora.merge(params, jax.random.PRNGKey(3), 0.5)
    assert float(jnp.abs(merged["B"]).max()) == 0.0  # factors restarted
    y2 = relora.rl_matmul(x, merged, 0.5)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-2)
