"""Sharding-rule unit tests + HLO cost-walker validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo_parser, roofline
from repro.dist import sharding as shl
from repro.models import registry


@pytest.fixture(scope="module")
def mesh4():
    # 1-device "mesh" with 4 logical axes is impossible; use (1,1) named mesh
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_param_specs_cover_every_leaf(mesh4):
    for arch in ("yi_34b", "qwen3_moe_235b", "zamba2_7b", "xlstm_350m",
                 "whisper_large_v3"):
        cfg = registry.get_config(arch)
        api = registry.get_api(cfg)
        params, consts = api.init(cfg, key=None)   # abstract — no alloc
        specs = shl.param_specs(params, mesh4)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(params)[0],
                jax.tree_util.tree_flatten_with_path(specs)[0]):
            assert isinstance(spec, P)
            assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)


def test_divisibility_guard():
    """Axes that don't divide fall back to replication, never crash."""
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    leaf = jax.ShapeDtypeStruct((56, 17), jnp.float32)  # 17 indivisible

    class FakeKey:
        def __init__(self, key):
            self.key = key
    spec = shl.spec_for_param((FakeKey("attn"), FakeKey("wq"),
                               FakeKey("w")), leaf, mesh)
    assert isinstance(spec, P)


def test_batch_specs_shard_when_divisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    b = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    specs = shl.batch_specs(b, mesh, ("data",))
    assert specs["tokens"][0] in (("data",), "data")
    b1 = {"tokens": jax.ShapeDtypeStruct((1, 16), jnp.int32)}
    # batch=1 with |data|=1 still divides; use a padded mesh impossible on
    # 1 CPU — the divisibility logic itself is unit-tested in dryrun.


# ---------------------------------------------------------------------------
# HLO cost walker
# ---------------------------------------------------------------------------

def test_walker_counts_scan_trips():
    def body(x, w):
        return jnp.tanh(x @ w), None
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((9, 128, 128), jnp.float32)
    c = jax.jit(lambda x, w: jax.lax.scan(body, x, w)[0]).lower(x, ws
                                                                ).compile()
    pc = hlo_parser.analyze(c.as_text())
    expect = 9 * 2 * 64 * 128 * 128
    assert abs(pc.flops - expect) / expect < 0.01
    assert pc.dot_calls == 9
    assert 9 in pc.trip_counts.values()


def test_walker_matmul_flops_and_bytes():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    pc = hlo_parser.analyze(c.as_text())
    assert abs(pc.flops - 2 * 256 * 512 * 1024) / pc.flops < 0.01
    expect_b = 4 * (256 * 512 + 512 * 1024 + 256 * 1024)
    assert abs(pc.hbm_bytes - expect_b) / expect_b < 0.05


def test_walker_detects_remat_recompute():
    """remat=full must raise dot_calls vs no-remat (recompute detector)."""
    def blk(x, w):
        return jnp.tanh(x @ w) @ w.T

    def loss(ws, x):
        def body(h, w):
            return blk(h, w), None
        h, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(h)

    def loss_remat(ws, x):
        def body(h, w):
            return jax.checkpoint(blk)(h, w), None
        h, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(h)

    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    d_plain = hlo_parser.analyze(
        jax.jit(jax.grad(loss)).lower(ws, x).compile().as_text()).dot_calls
    d_remat = hlo_parser.analyze(
        jax.jit(jax.grad(loss_remat)).lower(ws, x).compile().as_text()
    ).dot_calls
    assert d_remat > d_plain


def test_collective_wire_bytes_ring_factors():
    txt = """HloModule m, entry_computation_layout={(f32[1024]{0})->f32[1024]{0}}

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), replica_groups=[16,16]<=[256], to_apply=%add
}
"""
    pc = hlo_parser.analyze(txt)
    per = 2 * 15 / 16 * 4096
    assert abs(pc.wire_bytes - per * 16 * 16) < 1.0
    assert pc.coll_counts == {"all-reduce": 1}


def test_roofline_terms_and_bottleneck():
    rl = roofline.Roofline(flops=1e15, hbm_bytes=1e12, wire_bytes=1e12,
                           chips=256, model_flops=5e14)
    assert rl.t_compute == pytest.approx(1e15 / (256 * roofline.PEAK_FLOPS))
    assert rl.bottleneck in ("compute", "memory", "collective")
    assert 0 < rl.roofline_fraction <= 1.0
    assert rl.useful_flops_ratio == pytest.approx(0.5)


def test_model_flops_moe_uses_active_params():
    dense = registry.get_config("yi_34b")
    moe = registry.get_config("qwen3_moe_235b")
    tot_d, act_d = roofline.param_count_active(dense)
    tot_m, act_m = roofline.param_count_active(moe)
    assert tot_d == act_d                       # dense: all params active
    assert act_m < 0.25 * tot_m                 # 235B total / 22B active
