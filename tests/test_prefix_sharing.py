"""Tests for PR-6: copy-on-write prefix sharing over the paged KV cache,
the chunked-prefill Pallas kernel, and continuous batching.

Covers the refcount lifecycle as a property test (random
attach/ensure/release interleavings with colliding prefix families must
keep the allocator's accounting invariants and leak nothing), N-way
shared-prefix decode token-for-token against per-request ground truth,
the chunked-prefill kernel against its pure-jnp oracle AND an independent
contiguous dense-attention oracle AND the gather suffix-prefill path at
the engine level, the continuous-batching staggered-arrival regression,
and bounded-run unfinished-request reporting for both run loops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pinned env ships no hypothesis: seeded-loop shim
    from _propshim import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.models import registry
from repro.serve import kv as kv_lib
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def model():
    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    return cfg, api, params, consts


# ---------------------------------------------------------------------------
# Refcount lifecycle (property test)
# ---------------------------------------------------------------------------

def _family_prompt(family: int, plen: int):
    """Deterministic prompt from a small family id: same family ⇒ same
    token stream, so block-aligned prefixes collide across requests and
    the attach/register paths actually exercise sharing."""
    return [(family * 7 + i) % 11 + 3 for i in range(plen)]


@settings(max_examples=30, deadline=None)
@given(ops_list=st.lists(
    st.tuples(st.integers(0, 3),      # slot
              st.integers(2, 24),     # prompt length
              st.integers(0, 2)),     # prefix family
    min_size=1, max_size=40))
def test_refcount_lifecycle_property(ops_list):
    """Any interleaving of admissions (match→attach→ensure→register) and
    releases keeps the BlockTable invariants — refcounts equal live table
    references, the free list never double-lists a block, shared blocks
    outlive individual releases — and full teardown returns EVERY block
    to the free list (no leaks through the prefix map)."""
    layout = kv_lib.PagedLayout.plan(n_slots=4, max_len=32, block_len=4)
    bt = kv_lib.BlockTable(layout, n_slots=4)
    occupied = {}
    for slot, plen, family in ops_list:
        if slot in occupied:
            bt.release(slot)
            del occupied[slot]
        else:
            toks = _family_prompt(family, plen)
            chain = bt.match_prefix(toks, len(toks) - 1)
            shared = bt.attach(slot, chain)
            assert shared == len(chain) * layout.block_len
            assert shared <= len(toks) - 1  # ≥1 suffix token always left
            if not bt.ensure(slot, len(toks)):
                bt.release(slot)            # pool full: admission bounces
            else:
                bt.register_prefix(slot, toks, len(toks) - 1)
                occupied[slot] = toks
        bt.check()
    # re-admitting a seen family must now share its whole-block prefix
    for slot, toks in occupied.items():
        nshare = len(bt.match_prefix(toks, len(toks) - 1))
        assert nshare == (len(toks) - 1) // layout.block_len
    for slot in list(occupied):
        bt.release(slot)
        bt.check()
    assert bt.blocks_in_use == 0
    assert bt.free_blocks == layout.n_blocks - 1   # all but the null block
    assert (bt.table == 0).all()


def test_attach_refuses_freed_blocks_and_busy_slots():
    layout = kv_lib.PagedLayout.plan(n_slots=2, max_len=32, block_len=4)
    bt = kv_lib.BlockTable(layout, n_slots=2)
    toks = _family_prompt(0, 9)
    bt.ensure(0, len(toks))
    bt.register_prefix(0, toks, len(toks) - 1)
    chain = bt.match_prefix(toks, len(toks) - 1)
    assert chain                            # 2 full blocks resident
    with pytest.raises(AssertionError):     # attach onto a non-empty slot
        bt.attach(0, chain)
    bt.release(0)                           # last ref gone → chain is stale
    assert bt.match_prefix(toks, len(toks) - 1) == []
    with pytest.raises(AssertionError):     # attach to a freed block
        bt.attach(1, chain)


# ---------------------------------------------------------------------------
# Chunked-prefill kernel: vs oracle, vs independent dense attention
# ---------------------------------------------------------------------------

def _mk_prefill_case(rng, *, n_slots, block_len, bps, n_kv, n_heads, hd,
                     sq, offsets):
    """Random pools + block tables for a suffix-prefill chunk: slot s's
    chunk spans absolute positions [offsets[s], offsets[s] + sq); its
    K/V (prior pages AND the chunk) is already resident in the pools.
    ``offsets[s] < 0`` marks the slot idle (all-null table row)."""
    n_blocks = 1 + n_slots * bps
    k_pool = jnp.asarray(rng.standard_normal((n_blocks, block_len, n_kv, hd)),
                         jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((n_blocks, block_len, n_kv, hd)),
                         jnp.float32)
    table = np.zeros((n_slots, bps), np.int32)
    off = np.zeros(n_slots, np.int32)
    nid = 1
    for s, o in enumerate(offsets):
        if o < 0:
            continue
        off[s] = o
        for j in range(kv_lib.blocks_for(o + sq, block_len)):
            table[s, j] = nid
            nid += 1
    q = jnp.asarray(rng.standard_normal((n_slots, sq, n_heads, hd)),
                    jnp.float32)
    return q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(off)


@pytest.mark.parametrize("softcap,window", [(0.0, 0), (30.0, 0), (0.0, 12)])
def test_prefill_kernel_matches_oracle(softcap, window):
    """Kernel vs pure-jnp oracle across staggered offsets, a fresh slot
    (offset 0 — plain batched prefill), an idle slot, GQA grouping and
    partial tail blocks, under softcap and sliding-window variants."""
    rng = np.random.default_rng(7)
    q, kp, vp, tbl, off = _mk_prefill_case(
        rng, n_slots=4, block_len=8, bps=5, n_kv=2, n_heads=4, hd=16,
        sq=6, offsets=[16, 0, 11, -1])
    scale = 16 ** -0.5
    got = ops.paged_prefill_attention(q, kp, vp, tbl, off, scale=scale,
                                      softcap=softcap, window=window,
                                      interpret=True)
    q5 = q.reshape(4, 6, 2, 2, 16)
    want = ref.paged_prefill_ref(q5, kp, vp, tbl, off, scale=scale,
                                 softcap=softcap, window=window)
    np.testing.assert_allclose(got, want.reshape(got.shape), atol=2e-6)
    assert not np.isnan(np.asarray(got)).any()
    assert (np.asarray(got[3]) == 0).all()       # idle slot: exact zeros


def test_prefill_kernel_matches_contiguous_dense():
    """Independent oracle: scatter a contiguous sequence into pages, run
    the kernel as a whole-prompt prefill (offset 0), and compare against
    plain causal attention over the contiguous arrays — no paging code on
    the reference side at all."""
    rng = np.random.default_rng(3)
    bl, n_kv, n_heads, hd, total = 8, 2, 4, 16, 13
    k_seq = rng.standard_normal((total, n_kv, hd)).astype(np.float32)
    v_seq = rng.standard_normal((total, n_kv, hd)).astype(np.float32)
    n_blocks = 1 + kv_lib.blocks_for(total, bl)
    k_pool = rng.standard_normal((n_blocks, bl, n_kv, hd)).astype(np.float32)
    v_pool = rng.standard_normal((n_blocks, bl, n_kv, hd)).astype(np.float32)
    for t in range(total):                  # blocks 1.. hold the sequence
        k_pool[1 + t // bl, t % bl] = k_seq[t]
        v_pool[1 + t // bl, t % bl] = v_seq[t]
    table = np.zeros((1, 2), np.int32)
    table[0, :kv_lib.blocks_for(total, bl)] = np.arange(
        1, 1 + kv_lib.blocks_for(total, bl))
    q = rng.standard_normal((1, total, n_heads, hd)).astype(np.float32)
    scale = hd ** -0.5
    got = ops.paged_prefill_attention(
        jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(table), jnp.zeros(1, jnp.int32), scale=scale,
        interpret=True)
    # dense causal attention, contiguous arrays, f32 throughout
    g = n_heads // n_kv
    qg = q.reshape(total, n_kv, g, hd) * scale
    s = np.einsum("qhgd,lhd->qhgl", qg, k_seq)
    mask = np.arange(total)[None, :] <= np.arange(total)[:, None]
    s = np.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(jnp.asarray(s), axis=-1)
    want = np.einsum("qhgl,lhd->qhgd", np.asarray(p),
                     v_seq).reshape(1, total, n_heads, hd)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-6)


# ---------------------------------------------------------------------------
# Engine: shared-prefix decode, suffix prefill kernel vs gather
# ---------------------------------------------------------------------------

def _truth(model, prompts, n_new):
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=1, max_len=64,
                      paged=True, block_len=8)
    outs = []
    for p in prompts:
        r = eng.submit(p, max_new_tokens=n_new)
        eng.run_until_drained()
        outs.append(r.out)
    return outs


SHARED = [(i * 5 + 3) % 50 + 3 for i in range(16)]      # 2 full 8-blocks
TAILS = [[7, 9], [11, 4, 6], [13], [8, 8, 5, 9]]
SHARED_PROMPTS = [SHARED + t for t in TAILS]


@pytest.mark.parametrize("attn_kernel", ["gather", "paged"])
def test_nway_shared_prefix_decode_matches_truth(model, attn_kernel):
    """N requests opening with the same 16-token prefix: the first
    prefills it, the rest attach its pages read-only and prefill only
    their suffixes (through the gather view or the chunked-prefill
    kernel) — and every request still decodes token-for-token as if
    served alone. Afterwards all blocks are back on the free list."""
    cfg, api, params, consts = model
    singles = _truth(model, SHARED_PROMPTS, 6)
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=64,
                      paged=True, block_len=8, attn_kernel=attn_kernel,
                      prefix_sharing=True)
    reqs = [eng.submit(SHARED_PROMPTS[0], max_new_tokens=6)]
    eng.step()               # prefill req 0 → its prefix blocks register
    for p in SHARED_PROMPTS[1:]:
        reqs.append(eng.submit(p, max_new_tokens=6))
    stats = eng.run_until_drained()
    assert [r.out for r in reqs] == singles
    assert not stats["exhausted"]
    # requests 1..3 each attached the whole 16-token shared prefix
    pt = eng.prefill_traffic
    assert pt["tokens_shared"] == (len(SHARED_PROMPTS) - 1) * len(SHARED)
    assert pt["tokens_prefilled"] + pt["tokens_shared"] == pt["tokens_total"]
    eng.sched.blocks.check()
    assert eng.sched.blocks.blocks_in_use == 0   # COW frees recycled all


def test_shared_prefix_never_rewritten(model):
    """COW contract: attaching sharers must not touch the bytes of the
    shared physical pages (their suffix prefill writes land at positions
    ≥ the shared length, in their own fresh blocks)."""
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=64,
                      paged=True, block_len=8, prefix_sharing=True)
    # r0 decodes long enough to stay resident while every sharer cycles
    # through the other slot — its references pin the shared pages
    r0 = eng.submit(SHARED_PROMPTS[0], max_new_tokens=20)
    eng.step()
    shared_phys = eng.sched.blocks.table[0, :2].copy()   # 16 = 2 blocks
    assert (shared_phys > 0).all()
    before = jax.tree.map(np.asarray, eng.cache)
    reqs = [eng.submit(p, max_new_tokens=2) for p in SHARED_PROMPTS[1:]]
    eng.run_until_drained()
    after = jax.tree.map(np.asarray, eng.cache)
    checked = 0
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        if b.ndim == 5 and b.shape[1] == eng.layout.n_blocks:
            np.testing.assert_array_equal(b[:, shared_phys],
                                          a[:, shared_phys])
            checked += 1
    assert checked > 0       # the filter actually saw the K/V pools
    assert r0.done and all(r.done for r in reqs)
    assert eng.prefill_traffic["tokens_shared"] == 3 * len(SHARED)


# ---------------------------------------------------------------------------
# Continuous batching (run_stream)
# ---------------------------------------------------------------------------

def test_continuous_staggered_arrivals_match_truth(model):
    """Poisson-style staggered arrivals served via run_stream — requests
    admitted into recycled slots mid-decode — must each decode exactly as
    if served alone, and carry consistent tick stamps."""
    cfg, api, params, consts = model
    prompts = [[5, 9, 11], [7, 3, 2, 8, 6], [4, 4, 13], [9, 2], [6, 10, 3]]
    arrivals = [0, 1, 3, 9, 10]
    singles = _truth(model, prompts, 6)
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=64,
                      paged=True, block_len=8)
    reqs = [eng.submit(p, max_new_tokens=6, arrival=a)
            for p, a in zip(prompts, arrivals)]
    stats = eng.run_stream()
    assert [r.out for r in reqs] == singles
    assert not stats["exhausted"] and not stats["unfinished"]
    assert {r.uid for r in stats["completed"]} == {r.uid for r in reqs}
    for r in reqs:       # arrival ≤ first token ≤ done, on the same clock
        assert r.arrival < r.t_first <= r.t_done <= eng.clock


def test_continuous_with_sharing_matches_truth(model):
    """The acceptance bar: continuous batching + prefix sharing together,
    staggered arrivals, token-for-token vs per-request ground truth."""
    cfg, api, params, consts = model
    arrivals = [0, 2, 5, 11]
    singles = _truth(model, SHARED_PROMPTS, 6)
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=64,
                      paged=True, block_len=8, prefix_sharing=True)
    reqs = [eng.submit(p, max_new_tokens=6, arrival=a)
            for p, a in zip(SHARED_PROMPTS, arrivals)]
    stats = eng.run_stream()
    assert [r.out for r in reqs] == singles
    assert not stats["exhausted"]
    assert eng.prefill_traffic["tokens_shared"] > 0
    eng.sched.blocks.check()
    assert eng.sched.blocks.blocks_in_use == 0


def test_stream_not_admitted_before_arrival(model):
    """A request with a future arrival tick stays queued even when a slot
    is free; the idle engine fast-forwards its clock instead of spinning
    max_steps away."""
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=64,
                      paged=True, block_len=8)
    r = eng.submit([5, 9, 11], max_new_tokens=3, arrival=50)
    stats = eng.run_stream(max_steps=20)
    assert r.done and not stats["exhausted"]
    assert r.t_first > 50 and eng.clock >= 50


def test_stream_requires_paged(model):
    cfg, api, params, consts = model
    eng = ServeEngine(cfg, params, consts, n_slots=2, max_len=64)
    eng.submit([5, 9], max_new_tokens=2)
    with pytest.raises(ValueError, match="paged=True"):
        eng.run_stream()


# ---------------------------------------------------------------------------
# Bounded runs surface unfinished requests
# ---------------------------------------------------------------------------

def test_bounded_runs_report_unfinished(model):
    """max_steps exhaustion must return the leftover requests in the
    'unfinished' list (queued AND mid-decode), not drop them — for both
    the drain loop and the stream loop."""
    cfg, api, params, consts = model
    for runner in ("run_until_drained", "run_stream"):
        eng = ServeEngine(cfg, params, consts, n_slots=1, max_len=64,
                          paged=True, block_len=8)
        reqs = [eng.submit([5, 9, 11], max_new_tokens=30),
                eng.submit([7, 3], max_new_tokens=30)]
        if runner == "run_until_drained":
            with pytest.warns(UserWarning, match="max_steps"):
                stats = eng.run_until_drained(max_steps=3)
        else:
            stats = eng.run_stream(max_steps=3)
        assert stats["exhausted"] is True
        assert {r.uid for r in stats["unfinished"]} == \
            {r.uid for r in reqs}, runner
        assert not stats["completed"]
        # the same engine can resume and finish what it reported
        stats = getattr(eng, runner)()
        assert not stats["exhausted"]
        assert {r.uid for r in stats["completed"]} == {r.uid for r in reqs}
