"""Post-training int8 quantized serving (repro.quant): kernel parity
against the ref oracle and the dequantized-dense matmul across tile
shapes, calibration (per-channel scales, SVD error fold), model-level
decode parity on GQA configs, bit-exact quant-artifact and fused-const
checkpoint round-trips, the modeled decode-bytes gate, and the committed
BENCH_quant.json acceptance rows."""
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs.base import ModelConfig, ParamConfig
from repro.core import sltrain
from repro.core import support as support_lib
from repro.kernels import ops, ref
from repro.models import registry
from repro.quant import calibrate, layout

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _mk_linear(d_in, d_out, r, delta, seed=0, dtype=jnp.bfloat16):
    """One row-balanced SLTrain linear in model-tree form + its flat COO."""
    rng = np.random.default_rng(seed)
    rows, cols = support_lib.sample_support(seed + 1, d_in, d_out, delta,
                                            "row_balanced")
    k = rows.shape[0] // d_in
    v = (rng.standard_normal(rows.shape[0]) * 0.05).astype(np.float32)
    B = (rng.standard_normal((d_in, r)) * 0.05).astype(np.float32)
    A = (rng.standard_normal((r, d_out)) * 0.05).astype(np.float32)
    p = {"B": jnp.asarray(B, dtype), "A": jnp.asarray(A, dtype),
         "v": jnp.asarray(v.reshape(d_in, k), dtype)}
    c = {"cols": jnp.asarray(cols.reshape(d_in, k))}
    return p, c, np.asarray(rows), np.asarray(cols)


SHAPES = [
    (128, 128, 16, 0.03),     # single tile
    (256, 384, 16, 0.03),     # multi-tile, non-square
    (130, 250, 8, 0.05),      # dims not tile multiples (padding path)
    (384, 128, 8, 0.05),      # wide-in (GQA kv-proj shape: d_out < d_in)
]


@pytest.mark.parametrize("d_in,d_out,r,delta", SHAPES)
def test_quant_kernel_matches_ref_and_dequantized_dense(d_in, d_out, r,
                                                        delta):
    p, c, rows, cols = _mk_linear(d_in, d_out, r, delta)
    alpha, scale = 16.0, 16.0 / r
    vf = np.asarray(p["v"], np.float32).reshape(-1)
    W = scale * (np.asarray(p["B"], np.float32)
                 @ np.asarray(p["A"], np.float32))
    Wd = W.copy()
    Wd[rows, cols] += vf
    scales = layout.channel_scales(Wd)
    qv = layout.quantize_values(vf, cols, scales)
    qc = layout.build_quant_consts(rows, cols, qv, scales, d_in, d_out,
                                   delta, "row_balanced")
    x = jnp.asarray(np.random.default_rng(3).standard_normal((5, d_in)),
                    jnp.float32)

    y_k = ops.sl_quant_decode(x, p["B"], p["A"], qc["qv_t"], qc["rows_q"],
                              qc["cols_q"], qc["qscale"], scale)
    y_ref = ref.sl_quant_decode_ref(x, p["B"], p["A"], jnp.asarray(rows),
                                    jnp.asarray(cols), jnp.asarray(qv),
                                    jnp.asarray(scales), scale)
    Wq = scale * (np.asarray(p["B"], np.float32)
                  @ np.asarray(p["A"], np.float32))
    Wq[rows, cols] += layout.dequantize_values(qv, cols, scales)
    y_dense = np.asarray(x) @ Wq
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(y_ref, np.float32), y_dense,
                               atol=1e-4, rtol=1e-4)
    # the sl_matmul dispatch reaches the same kernel (consts-gated)
    y_d = sltrain.sl_matmul(x, p, {**c, **qc}, scale, "quant")
    np.testing.assert_allclose(np.asarray(y_d, np.float32),
                               np.asarray(y_k, np.float32),
                               atol=1e-4, rtol=1e-4)


def test_quantize_linear_shapes_dtypes_and_error_fold():
    d_in, d_out, r, delta = 256, 384, 16, 0.05
    p, c, rows, cols = _mk_linear(d_in, d_out, r, delta)
    outs = {}
    for fold in (False, True):
        np_, qc, st = calibrate.quantize_linear(
            p, c, alpha=16.0, delta=delta, support_kind="row_balanced",
            fold_error=fold)
        assert np_["B"].shape == p["B"].shape and np_["B"].dtype == \
            p["B"].dtype
        assert np_["A"].shape == p["A"].shape and np_["A"].dtype == \
            p["A"].dtype
        cap = support_lib.tile_cap(d_in, d_out, delta, "row_balanced")
        nkt, nnt = -(-d_in // 128), -(-d_out // 128)
        assert qc["qv_t"].shape == (nkt, nnt, cap) and \
            qc["qv_t"].dtype == jnp.int8
        assert qc["rows_q"].dtype == jnp.int16 and \
            qc["cols_q"].dtype == jnp.int16
        assert qc["qscale"].shape == (nnt, 128) and \
            qc["qscale"].dtype == jnp.float32
        # layout geometry matches the abstract twin exactly (dry-run)
        abstract = layout.abstract_quant_consts(d_in, d_out, delta,
                                                "row_balanced")
        for k in qc:
            assert qc[k].shape == abstract[k].shape
            assert qc[k].dtype == abstract[k].dtype
        outs[fold] = st
    # without fold: B/A unchanged bit-for-bit
    np_nf, _, _ = calibrate.quantize_linear(
        p, c, alpha=16.0, delta=delta, support_kind="row_balanced",
        fold_error=False)
    assert np.array_equal(np.asarray(np_nf["B"]).view(np.uint16),
                          np.asarray(p["B"]).view(np.uint16))
    # the SVD fold strictly reduces the dense-equivalent quant error
    assert outs[True]["max_abs_err"] < outs[False]["max_abs_err"]
    # symmetric codes: negation round-trips (-128 never emitted)
    assert int(np.min(np.asarray(qc["qv_t"]))) >= -127


def _tiny_cfg(n_kv_heads):
    return ModelConfig(
        name=f"quant-gqa{n_kv_heads}", family="llama",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=n_kv_heads,
        d_ff=160, vocab_size=256, vocab_pad_multiple=16, max_seq_len=64,
        param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=16.0))


@pytest.mark.parametrize("n_kv_heads", [4, 2, 1])
def test_model_level_quant_parity_across_gqa(n_kv_heads):
    """Full-model apply: quant vs bf16-sparse logits stay close and agree
    on greedy argmax, including grouped-query configs where the kv
    projections are rectangular (d_out = n_kv_heads * head_dim < d_in)."""
    cfg = _tiny_cfg(n_kv_heads)
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    qp, qc, stats = calibrate.calibrate_model(cfg, params, consts)
    assert stats["n_matrices"] > 0
    tok = jnp.asarray(np.random.default_rng(1).integers(
        3, cfg.vocab_size, size=(2, 16)), jnp.int32)
    cfg_sp = dataclasses.replace(
        cfg, param=dataclasses.replace(cfg.param, exec_mode="sparse"))
    cfg_q = dataclasses.replace(
        cfg, param=dataclasses.replace(cfg.param, exec_mode="quant"))
    lg_sp, _ = api.apply(cfg_sp, params, consts, {"tokens": tok})
    lg_q, _ = api.apply(cfg_q, qp, qc, {"tokens": tok})
    a = np.asarray(lg_sp, np.float32)[..., :cfg.vocab_size]
    b = np.asarray(lg_q, np.float32)[..., :cfg.vocab_size]
    assert np.abs(a - b).mean() < 0.05, np.abs(a - b).mean()
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.99


def test_quant_artifact_roundtrip_bit_exact(tmp_path):
    cfg = _tiny_cfg(2)
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    qp, qc, stats = calibrate.calibrate_model(cfg, params, consts)
    out = str(tmp_path / "artifact")
    ckpt_lib.save_quant_artifact(out, qp, qc, config_hash="h",
                                 extra=stats)
    rp, rc, man = ckpt_lib.load_quant_artifact(out)
    assert man["format"] == ckpt_lib.QUANT_FORMAT
    assert man["extra"]["n_matrices"] == stats["n_matrices"]

    def flatten(tree):
        return {
            "/".join(str(getattr(k, "key", k)) for k in path): leaf
            for path, leaf in
            jax.tree_util.tree_flatten_with_path(tree)[0]}

    for saved, loaded in ((flatten(qp), flatten(rp)),
                          (flatten(qc), flatten(rc))):
        assert saved.keys() == loaded.keys()
        for key, a in saved.items():
            b = loaded[key]
            a, b = np.asarray(a), np.asarray(b)
            assert a.dtype == b.dtype, key
            view = np.uint16 if a.dtype == jnp.bfloat16 else a.dtype
            assert np.array_equal(a.view(view), b.view(view)), key
    # version gate: stale/foreign formats refuse to load
    man_path = tmp_path / "artifact" / "manifest.json"
    bad = json.loads(man_path.read_text())
    bad["format"] = "sltrain-quant-v0"
    man_path.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="format"):
        ckpt_lib.load_quant_artifact(out)


def test_ckpt_roundtrip_fused_tile_consts_bit_identical(tmp_path):
    """Satellite: fused-mode tile consts (rows_t/cols_t/perm) and the
    flat bf16 v survive a CheckpointManager save/restore cycle
    bit-for-bit — int32 consts have no tolerance to hide behind."""
    cfg = dataclasses.replace(
        _tiny_cfg(4),
        param=dataclasses.replace(_tiny_cfg(4).param, exec_mode="fused"))
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    cm = ckpt_lib.CheckpointManager(str(tmp_path / "ckpt"))
    cm.save(0, {"params": params, "consts": consts}, config_hash="h")
    tree, _ = cm.restore({"params": params, "consts": consts},
                         config_hash="h")

    flat_in = ckpt_lib._flatten_with_paths({"params": params,
                                            "consts": consts})[0]
    flat_out = ckpt_lib._flatten_with_paths(tree)[0]
    assert flat_in.keys() == flat_out.keys()
    checked = {"rows_t": 0, "cols_t": 0, "perm": 0, "v": 0}
    for key, a in flat_in.items():
        b = flat_out[key]
        assert a.dtype == b.dtype and np.array_equal(a, b), key
        leaf = key.rsplit("/", 1)[-1]
        if leaf in checked:
            checked[leaf] += 1
    assert all(n > 0 for n in checked.values()), checked


@pytest.mark.parametrize("d_in,d_out", [(512, 512), (768, 2048),
                                        (2048, 768)])
def test_modeled_decode_bytes_reduction_at_least_2x(d_in, d_out):
    bf16 = layout.sparse_decode_bytes(d_in, d_out, 0.03, quant=False)
    int8 = layout.sparse_decode_bytes(d_in, d_out, 0.03, quant=True)
    assert bf16 / int8 >= 2.0, (d_in, d_out, bf16 / int8)


def test_bench_snapshot_quant_gates():
    """The committed BENCH_quant.json must carry BOTH acceptance rows
    with passing values — the end-to-end gate, asserted on the artifact
    so it cannot silently go stale-green."""
    path = REPO_ROOT / "BENCH_quant.json"
    assert path.exists(), "run: PYTHONPATH=src python -m benchmarks.run " \
                          "--only quant"
    rows = json.loads(path.read_text())["rows"]
    by = {r["row"]: r for r in rows if r.get("bench") == "quant_serve"}
    gm = by["greedy_match"]
    from benchmarks import quant_bench
    assert gm["match_rate"] >= quant_bench.MIN_MATCH_RATE or \
        gm["mean_abs_dlogit"] <= quant_bench.MAX_MEAN_ABS_DLOGIT, gm
    db = by["decode_bytes"]
    assert db["reduction_x"] >= quant_bench.MIN_BYTES_REDUCTION, db


def test_quant_mode_validation_everywhere():
    cfg = _tiny_cfg(4)
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    from repro.serve.engine import ServeEngine
    # engine: quant without calibrated consts fails at construction
    with pytest.raises(ValueError, match="calibrated consts"):
        ServeEngine(cfg, params, consts, n_slots=1, max_len=32,
                    exec_mode="quant")
    with pytest.raises(ValueError, match="not both"):
        ServeEngine(cfg, params, consts, n_slots=1, max_len=32,
                    sparse_decode=True, exec_mode="sparse")
    with pytest.raises(ValueError, match="unknown exec_mode"):
        ServeEngine(cfg, params, consts, n_slots=1, max_len=32,
                    exec_mode="int8")
    # dispatch: quant without quant consts is a loud error
    p, c, _, _ = _mk_linear(128, 128, 8, 0.05)
    with pytest.raises(ValueError, match="quant"):
        sltrain.sl_matmul(jnp.ones((2, 128)), p, c, 1.0, "quant")
    # training rejects the serve-only mode
    from repro.configs.base import OptimizerConfig
    from repro.optim import optimizers
    from repro.train import step as step_lib
    cfg_q = dataclasses.replace(
        cfg, param=dataclasses.replace(cfg.param, exec_mode="quant"))
    with pytest.raises(ValueError, match="serve-only"):
        step_lib.make_train_step(cfg_q, api,
                                 optimizers.make(OptimizerConfig()))
    # ...but eval still works on quant consts (ppl measurement path)
    qp, qc, _ = calibrate.calibrate_model(cfg, params, consts)
    ev = step_lib.make_eval_step(cfg_q, api)
    tok = jnp.ones((1, 8), jnp.int32)
    out = ev(qp, qc, {"tokens": tok})
    assert np.isfinite(float(out["loss"]))
