"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
ref.py pure-jnp oracle (assignment requirement for every Pallas kernel)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import support as support_lib
from repro.kernels import ops, ref
from repro.optim import quant


def _mk(d_in, d_out, r, m, delta, dtype, seed=0):
    rng = np.random.default_rng(seed)
    rows, cols = support_lib.sample_support(seed + 1, d_in, d_out, delta,
                                            "row_balanced")
    v = (rng.standard_normal(rows.shape[0]) * 0.05).astype(np.float32)
    x = jnp.asarray(rng.standard_normal((m, d_in)), dtype)
    B = jnp.asarray(rng.standard_normal((d_in, r)) * 0.05, dtype)
    A = jnp.asarray(rng.standard_normal((r, d_out)) * 0.05, dtype)
    tiles = ops.prepare_tiles(rows, cols, v, d_in, d_out)
    return x, B, A, jnp.asarray(rows), jnp.asarray(cols), \
        jnp.asarray(v).astype(dtype), tiles


SHAPES = [
    (128, 128, 16, 64, 0.03),     # single tile
    (256, 384, 32, 200, 0.03),    # multi-tile, non-square, unaligned m
    (130, 250, 8, 64, 0.05),      # dims not tile multiples (padding path)
    (512, 256, 64, 128, 0.01),    # sparse-light
]


@pytest.mark.parametrize("d_in,d_out,r,m,delta", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sl_matmul_matches_oracle(d_in, d_out, r, m, delta, dtype):
    x, B, A, rows, cols, v, (v_t, r_t, c_t, perm) = _mk(
        d_in, d_out, r, m, delta, dtype)
    y = ops.sl_matmul(x, B, A, v_t, r_t, c_t, 0.25)
    y_ref = ref.sl_matmul_ref(x, B, A, rows, cols, v, 0.25)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("d_in,d_out,r,m,delta", SHAPES[:3])
def test_sddmm_matches_oracle(d_in, d_out, r, m, delta):
    x, B, A, rows, cols, v, (v_t, r_t, c_t, perm) = _mk(
        d_in, d_out, r, m, delta, jnp.float32)
    dy = jnp.asarray(np.random.default_rng(1).standard_normal((m, d_out)),
                     jnp.float32)
    dv_t = ops.sddmm(x, dy, r_t, c_t)
    dv_ref = ref.sddmm_ref(x, dy, rows, cols)
    # map tile values back to COO order via perm
    perm_np = np.asarray(perm).reshape(-1)
    flat = np.asarray(dv_t).reshape(-1)
    recon = np.zeros(rows.shape[0], np.float32)
    mask = perm_np >= 0
    recon[perm_np[mask]] = flat[mask]
    np.testing.assert_allclose(recon, np.asarray(dv_ref), atol=1e-3,
                               rtol=1e-3)


def test_fused_vjp_matches_core_autodiff():
    """The pallas custom-VJP linear must produce the same gradients as the
    XLA densify path in core.sltrain (paper eq. 2)."""
    from repro.core import sltrain
    d_in, d_out, r, m, delta = 256, 384, 32, 96, 0.03
    params, consts = sltrain.init_params(
        jax.random.PRNGKey(1), d_in, d_out, r, delta, jnp.float32,
        "row_balanced", seed=7)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((m, d_in)), jnp.float32)
    dy = jnp.asarray(rng.standard_normal((m, d_out)), jnp.float32)
    scale = 0.5

    gc = jax.grad(lambda p: jnp.sum(
        sltrain.sl_matmul(x, p, consts, scale) * dy))(params)

    cols_rb = np.asarray(consts["cols"])
    k = cols_rb.shape[1]
    rows2 = np.repeat(np.arange(d_in, dtype=np.int32), k)
    cols2 = cols_rb.reshape(-1)
    v2 = np.asarray(params["v"]).reshape(-1)
    v_t, r_t, c_t, perm = ops.prepare_tiles(rows2, cols2, v2, d_in, d_out)

    gB, gA, gvt = jax.grad(
        lambda B, A, vt: jnp.sum(
            ops.sl_linear_fused(x, B, A, vt, r_t, c_t, scale) * dy),
        argnums=(0, 1, 2))(params["B"], params["A"], v_t)

    np.testing.assert_allclose(np.asarray(gB), np.asarray(gc["B"]),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gA), np.asarray(gc["A"]),
                               atol=1e-3, rtol=1e-3)
    perm_np = np.asarray(perm).reshape(-1)
    mask = perm_np >= 0
    recon = np.zeros(rows2.shape[0], np.float32)
    recon[perm_np[mask]] = np.asarray(gvt).reshape(-1)[mask]
    np.testing.assert_allclose(recon, np.asarray(gc["v"]).reshape(-1),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("n", [256, 1000, 64 * 256 + 3])
@pytest.mark.parametrize("wd", [0.0, 0.1])
def test_adam8bit_matches_oracle(n, wd):
    rng = np.random.default_rng(int(n + wd * 10))
    p = jnp.asarray(rng.standard_normal(n), jnp.float32)
    g = jnp.asarray(rng.standard_normal(n), jnp.float32)
    m0 = jnp.asarray(rng.standard_normal(n) * 0.1, jnp.float32)
    v0 = jnp.asarray(np.abs(rng.standard_normal(n)) * 0.01, jnp.float32)
    mc, ms, _ = quant.quantize_blockwise(m0, 256, True)
    vc, vs, _ = quant.quantize_blockwise(v0, 256, False)
    kw = dict(lr=1e-3, b1=0.9, b2=0.999, bc1=0.2, bc2=0.01, eps=1e-8, wd=wd)
    newp, mc2, ms2, vc2, vs2 = ops.adam8bit_update(p, g, mc, ms, vc, vs, **kw)
    pad = (-n) % 256
    pp = jnp.pad(p, (0, pad)).reshape(-1, 256)
    gg = jnp.pad(g, (0, pad)).reshape(-1, 256)
    scalars = jnp.array([kw["lr"], kw["b1"], kw["b2"], 1 - kw["b1"],
                         1 - kw["b2"], kw["bc1"], kw["bc2"],
                         kw["eps"], kw["wd"], 0.0])
    rp, rmc, rms, rvc, rvs = ref.adam8bit_ref(
        pp, gg, mc.reshape(-1, 256), ms, vc.reshape(-1, 256), vs, scalars,
        n_valid=n)
    np.testing.assert_allclose(np.asarray(newp),
                               np.asarray(rp).reshape(-1)[:n], atol=2e-5)
    assert (np.asarray(mc2) == np.asarray(rmc)).all()
    assert (np.asarray(vc2) == np.asarray(rvc)).all()


@pytest.mark.parametrize("n", [255, 256, 257, 100, 5 * 256 + 13])
def test_adam8bit_tail_blocks_track_quant_reference(n):
    """ISSUE-4 tail audit regression: over a multi-step trajectory with
    sizes straddling q_block (q±1, single partial block, multi-block with
    tail), the fused kernel must stay BITWISE identical to the
    optim/quant.py reference round-trip — codes exactly (including the
    padded tail lanes, which the kernel now masks to zero like the
    reference's re-pad), scales to ~1 f32 ulp (FMA contraction may differ
    between the interpret-mode kernel and fused XLA), params to ulp noise.
    The padded tail must never contaminate the last real block's scale."""
    rng = np.random.default_rng(n)
    p8 = jnp.asarray(rng.standard_normal(n), jnp.float32)
    pr = p8
    mc, ms, _ = quant.quantize_blockwise(jnp.zeros(n), 256, True)
    vc, vs, _ = quant.quantize_blockwise(jnp.zeros(n), 256, False)
    mrc, mrs, vrc, vrs = mc, ms, vc, vs
    b1, b2, lr, eps = 0.9, 0.999, 0.01, 1e-8
    for t in range(1, 12):
        g = rng.standard_normal(n)
        # decay the tail block's real gradients so a pad-lane leak (the old
        # 0.5-floor round-trip) would eventually dominate the block max
        g[-(n % 256 or 256):] *= 0.5 ** t
        g = jnp.asarray(g, jnp.float32)
        bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t
        p8, mc, ms, vc, vs = ops.adam8bit_update(
            p8, g, mc, ms, vc, vs, lr=lr, b1=b1, b2=b2, bc1=bc1, bc2=bc2,
            eps=eps, wd=0.0)
        m = quant.dequantize_blockwise(mrc, mrs, n, (n,), True)
        v = quant.dequantize_blockwise(vrc, vrs, n, (n,), False)
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        pr = pr - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        mrc, mrs, _ = quant.quantize_blockwise(m, 256, True)
        vrc, vrs, _ = quant.quantize_blockwise(v, 256, False)
        assert (np.asarray(mc) == np.asarray(mrc)).all(), t
        assert (np.asarray(vc) == np.asarray(vrc)).all(), t
        np.testing.assert_allclose(np.asarray(ms), np.asarray(mrs),
                                   rtol=1e-6, atol=0)
        np.testing.assert_allclose(np.asarray(vs), np.asarray(vrs),
                                   rtol=1e-6, atol=0)
        np.testing.assert_allclose(np.asarray(p8), np.asarray(pr), atol=1e-6)


def test_adam8bit_converges_like_fp32_adam():
    """Optimizing a quadratic with the fused 8-bit kernel should track the
    f32 Adam trajectory to within quantization error."""
    n = 512
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.standard_normal(n), jnp.float32)
    p8 = jnp.zeros(n)
    p32 = jnp.zeros(n)
    mc, ms, _ = quant.quantize_blockwise(jnp.zeros(n), 256, True)
    vc, vs, _ = quant.quantize_blockwise(jnp.zeros(n), 256, False)
    m32 = jnp.zeros(n)
    v32 = jnp.zeros(n)
    b1, b2, lr, eps = 0.9, 0.999, 0.05, 1e-8
    for t in range(1, 60):
        g8 = p8 - target
        g32 = p32 - target
        bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t
        p8, mc, ms, vc, vs = ops.adam8bit_update(
            p8, g8, mc, ms, vc, vs, lr=lr, b1=b1, b2=b2, bc1=bc1, bc2=bc2,
            eps=eps, wd=0.0)
        m32 = b1 * m32 + (1 - b1) * g32
        v32 = b2 * v32 + (1 - b2) * g32 * g32
        p32 = p32 - lr * (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
    err8 = float(jnp.abs(p8 - target).mean())
    err32 = float(jnp.abs(p32 - target).mean())
    assert err8 < err32 + 0.05, (err8, err32)


@pytest.mark.parametrize("d_in,d_out,m", [(256, 384, 1), (128, 128, 16),
                                          (130, 250, 7)])
def test_sparse_decode_kernel_matches_densify(d_in, d_out, m):
    """Factored decode kernel (x·B·A + x·S, S never in HBM) must equal the
    densified oracle (beyond-paper decode path, DESIGN §3)."""
    x, B, A, rows, cols, v, (v_t, r_t, c_t, perm) = _mk(
        d_in, d_out, 16, m, 0.05, jnp.float32, seed=3)
    y = ops.sl_decode(x, B, A, v_t, r_t, c_t, 0.5)
    y_ref = ref.sl_decode_ref(x, B, A, rows, cols, v, 0.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
