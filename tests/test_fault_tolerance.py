"""End-to-end fault tolerance (repro.resilience): deterministic fault
injection, non-finite skip/rollback escalation, checksummed checkpoints
with newest-intact fallback, and kill/relaunch bit-exactness.

Every fault the chaos harness can inject is driven to a VERIFIED
recovery here — the recovery counters are asserted on the obs registry,
not inferred from log lines."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _propshim import given, settings, strategies as st

from repro.ckpt.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.configs.base import OptimizerConfig, TrainConfig
from repro.data.pipeline import SyntheticC4
from repro.models import registry
from repro.obs import metrics as obs_metrics
from repro.resilience import ChaosEngine, ChaosKill, Fault
from repro.resilience.chaos import corrupt_npz
from repro.train import step as step_lib
from repro.train.trainer import Trainer


def _tc(tmp, steps=6, ckpt_every=0, **kw):
    cfg = registry.get_smoke_config("llama_60m")
    if "exec_mode" in kw:
        cfg = dataclasses.replace(
            cfg, param=dataclasses.replace(cfg.param,
                                           exec_mode=kw.pop("exec_mode")))
    return TrainConfig(model=cfg,
                       optim=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=steps),
                       global_batch=4, seq_len=32, steps=steps,
                       log_every=100, ckpt_every=ckpt_every, ckpt_dir=tmp,
                       async_ckpt=False, **kw)


# ---------------------------------------------------------------------------
# Chaos harness: spec parsing, fire-once semantics
# ---------------------------------------------------------------------------

def test_chaos_parse_spec():
    eng = ChaosEngine.parse("kill@3,nonfinite@5,straggler@4:50", seed=7)
    assert eng.faults == [Fault("kill", 3), Fault("nonfinite", 5),
                          Fault("straggler", 4, 50)]
    assert eng.wants_poison
    assert not ChaosEngine.parse("kill@1").wants_poison


@pytest.mark.parametrize("bad", ["frobnicate@3", "kill", "kill@x", ""])
def test_chaos_parse_rejects_bad_spec(bad):
    with pytest.raises(ValueError):
        ChaosEngine.parse(bad)


def test_chaos_fires_at_most_once():
    eng = ChaosEngine.parse("nonfinite@3")
    assert eng.poison_scale(2) == 1.0
    assert np.isnan(eng.poison_scale(5))     # first opportunity at/after 3
    assert eng.poison_scale(5) == 1.0        # never again (fire-once)
    k = ChaosEngine.parse("kill@0")
    with pytest.raises(SystemExit) as ei:
        k.train_hook(0)
    assert ei.value.code == ChaosKill.EXIT_CODE == 43
    k.train_hook(0)                          # already fired: no-op


# ---------------------------------------------------------------------------
# Checksummed checkpoints: manifest integrity, corrupt fallback, stale tmp
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 64), scale=st.floats(-4.0, 4.0),
       bf16=st.booleans())
def test_checksum_manifest_property(n, scale, bf16):
    """Property: every saved leaf has a CRC32 recorded AS STORED, the
    manifest digest matches a recompute, and a single flipped byte in
    arrays.npz turns restore into CheckpointCorruptError."""
    from repro.ckpt.checkpoint import _crc, _manifest_digest
    tree = {"w": jnp.arange(n, dtype=jnp.float32) * scale,
            "b": (jnp.ones(3, jnp.bfloat16) * scale if bf16
                  else jnp.full(3, scale, jnp.float32))}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(1, tree, config_hash="h")
        import json
        with open(os.path.join(d, "step_00000001", "manifest.json")) as f:
            man = json.load(f)
        assert set(man["checksums"]) == {"w", "b"}
        assert man["digest"] == _manifest_digest(man)
        stored_b = np.asarray(tree["b"])
        if bf16:
            stored_b = stored_b.view(np.uint16)   # CRC is post bit-view
        assert man["checksums"]["b"] == _crc(stored_b)
        assert cm.verify_step(1)
        corrupt_npz(os.path.join(d, "step_00000001", "arrays.npz"),
                    seed=n)
        assert not cm.verify_step(1)
        with pytest.raises(CheckpointCorruptError):
            cm.restore(tree, step=1, config_hash="h")


def test_corrupt_ckpt_falls_back_to_previous_step():
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(1, {"w": tree["w"]})
        cm.save(2, {"w": tree["w"] * 2})
        corrupt_npz(os.path.join(d, "step_00000002", "arrays.npz"))
        with pytest.warns(UserWarning, match="corrupt"):
            out, man = cm.restore(tree)
        assert man["step"] == 1
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(16, dtype=np.float32))
        # explicit step: no fallback, the damage is the caller's answer
        with pytest.raises(CheckpointCorruptError):
            cm.restore(tree, step=2)


def test_corrupt_manifest_detected():
    tree = {"w": jnp.zeros(4)}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(1, tree)
        man_path = os.path.join(d, "step_00000001", "manifest.json")
        with open(man_path) as f:
            text = f.read()
        with open(man_path, "w") as f:
            f.write(text.replace('"step": 1', '"step": 999'))
        with pytest.raises(CheckpointCorruptError, match="digest"):
            cm.restore(tree, step=1)


def test_stale_tmp_ignored_and_cleaned_on_next_save():
    tree = {"w": jnp.zeros(4)}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(3, tree)
        stale = os.path.join(d, "step_00000007.tmp")
        os.makedirs(stale)               # crash mid-publish leftover
        with open(os.path.join(stale, "junk"), "w") as f:
            f.write("partial")
        assert cm.all_steps() == [3]     # tmp never counts as a step
        assert cm.latest_step() == 3
        out, man = cm.restore(tree)      # and never participates in restore
        assert man["step"] == 3
        cm.save(4, tree)                 # next save sweeps it
        assert not os.path.exists(stale)
        assert cm.all_steps() == [3, 4]


# ---------------------------------------------------------------------------
# Non-finite gate: the skip-step primitive, global and per-layer
# ---------------------------------------------------------------------------

def _one_step_setup():
    from repro.optim import optimizers as opt_lib
    cfg = registry.get_smoke_config("llama_60m")
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    opt = opt_lib.make(OptimizerConfig(lr=1e-3, warmup_steps=1,
                                       total_steps=4))
    opt_state = opt.init(params)
    batch = {k: jnp.asarray(v) for k, v in
             SyntheticC4(cfg.vocab_size, 32, 4, seed=0).next_batch().items()}
    return cfg, api, opt, params, opt_state, consts, batch


@pytest.mark.parametrize("update_mode", ["global", "per_layer"])
def test_nonfinite_step_is_skipped_bit_exact(update_mode):
    """A NaN chaos_scale must leave params AND optimizer state bit-exactly
    untouched (metrics report nonfinite=1); scale=1.0 must be a no-op on
    the numerics vs the same step without the key."""
    cfg, api, opt, params, opt_state, consts, batch = _one_step_setup()
    if update_mode == "global":
        tstep = jax.jit(step_lib.make_train_step(cfg, api, opt))
    else:
        from repro.train import perlayer
        tstep = jax.jit(perlayer.make_perlayer_train_step(cfg, api, opt))
    b = batch["tokens"].shape[0]
    poisoned = dict(batch,
                    chaos_scale=jnp.full((b,), jnp.nan, jnp.float32))
    p2, o2, m = tstep(params, opt_state, consts, poisoned)
    assert float(m["nonfinite"]) == 1.0
    for a, c in zip(jax.tree.leaves((params, opt_state)),
                    jax.tree.leaves((p2, o2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    # scale 1.0: same update as the plain batch, and nonfinite=0
    clean = dict(batch, chaos_scale=jnp.ones((b,), jnp.float32))
    p3, _, m3 = tstep(params, opt_state, consts, clean)
    p_ref, _, m_ref = tstep(params, opt_state, consts, batch)
    assert float(m3["nonfinite"]) == 0.0
    assert float(m3["loss"]) == pytest.approx(float(m_ref["loss"]),
                                              rel=1e-6)
    for a, c in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), rtol=1e-6)


# ---------------------------------------------------------------------------
# Trainer escalation: skip -> rollback -> give up; data validation
# ---------------------------------------------------------------------------

def test_trainer_transient_nonfinite_skips_without_rollback():
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(_tc(d, steps=5), chaos=ChaosEngine.parse("nonfinite@2"),
                     max_skips=2)
        state = tr.run()
        assert state.step == 5
        snap = tr.obs.snapshot()
        assert snap["resilience.nonfinite_steps"]["value"] == 1
        assert snap["resilience.rollbacks"]["value"] == 0
        assert snap["resilience.faults_injected{kind=nonfinite}"][
            "value"] == 1
        # exactly one row skipped, and training went on to finish finite
        assert sum(r["nonfinite"] for r in tr.metrics_history) == 1.0
        assert np.isfinite(tr.metrics_history[-1]["loss"])


def test_trainer_rollback_restores_checkpoint_and_skips_data():
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(_tc(d, steps=6, ckpt_every=2),
                     chaos=ChaosEngine.parse("nonfinite@3"), max_skips=1)
        state = tr.run()
        assert state.step == 6
        snap = tr.obs.snapshot()
        assert snap["resilience.rollbacks"]["value"] == 1
        assert tr._rollbacks == 1
        assert np.isfinite(tr.metrics_history[-1]["loss"])


def test_trainer_gives_up_past_max_rollbacks():
    with tempfile.TemporaryDirectory() as d:
        chaos = ChaosEngine(
            [Fault("nonfinite", i) for i in range(3, 9)])
        tr = Trainer(_tc(d, steps=10, ckpt_every=2), chaos=chaos,
                     max_skips=1, max_rollbacks=1)
        with pytest.raises(RuntimeError, match="rollback"):
            tr.run()


def test_trainer_drops_corrupt_batches():
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(_tc(d, steps=4),
                     chaos=ChaosEngine.parse("data_corrupt@2"))
        state = tr.run()
        assert state.step == 4
        snap = tr.obs.snapshot()
        assert snap["resilience.bad_batches"]["value"] >= 1
        assert snap["resilience.faults_injected{kind=data_corrupt}"][
            "value"] == 1
        assert np.isfinite(tr.metrics_history[-1]["loss"])


def test_injected_straggler_is_flagged_by_watchdog():
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(_tc(d, steps=12),
                     chaos=ChaosEngine.parse("straggler@10:600"))
        tr.run()
        snap = tr.obs.snapshot()
        assert snap["resilience.faults_injected{kind=straggler}"][
            "value"] == 1
        assert tr.watchdog.flagged, "600ms injected sleep not flagged"


# ---------------------------------------------------------------------------
# Kill + relaunch: bit-exact continuation, dense AND fused
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("exec_mode", ["dense", "fused"])
def test_chaos_kill_relaunch_bit_exact(exec_mode):
    """ChaosKill at step 4 (exit 43), relaunch into the same ckpt dir:
    the continuation's per-step losses and final params must be
    bit-identical to an uninterrupted run."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        ref = Trainer(_tc(d1, steps=6, ckpt_every=2, exec_mode=exec_mode))
        ref_state = ref.run()

        tr = Trainer(_tc(d2, steps=6, ckpt_every=2, exec_mode=exec_mode),
                     chaos=ChaosEngine.parse("kill@4"))
        with pytest.raises(SystemExit) as ei:
            tr.run()
        assert ei.value.code == 43
        snap = tr.obs.snapshot()
        assert snap["resilience.faults_injected{kind=kill}"]["value"] == 1

        tr2 = Trainer(_tc(d2, steps=6, ckpt_every=2, exec_mode=exec_mode))
        state2 = tr2.run()
        assert state2.step == 6
        # loss continuation: the resumed steps reproduce the reference
        ref_by_step = {r["step"]: r["loss"] for r in ref.metrics_history}
        for r in tr2.metrics_history:
            assert r["loss"] == ref_by_step[r["step"]], (r, exec_mode)
        for a, b in zip(jax.tree.leaves(ref_state.params),
                        jax.tree.leaves(state2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_relaunch_falls_back_past_corrupted_newest_ckpt():
    """kill@5 then the newest checkpoint's arrays corrupted on disk: the
    relaunch must verify, warn, and resume from the previous intact step
    — never load garbage weights."""
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(_tc(d, steps=8, ckpt_every=2),
                     chaos=ChaosEngine.parse("kill@5"))
        with pytest.raises(SystemExit):
            tr.run()
        corrupt_npz(os.path.join(d, "step_00000004", "arrays.npz"))
        logs = []
        with pytest.warns(UserWarning, match="corrupt"):
            tr2 = Trainer(_tc(d, steps=8, ckpt_every=2),
                          log_fn=logs.append)
            state = tr2.run()
        assert state.step == 8
        assert any("resumed from step 2" in l for l in logs), logs


# ---------------------------------------------------------------------------
# Fault-matrix acceptance: every train-side kind -> verified recovery
# ---------------------------------------------------------------------------

def test_fault_matrix_every_kind_recovers():
    """One shared registry across kill + relaunch: all five train-side
    fault kinds injected, run completes, and every recovery counter is
    present in the snapshot."""
    reg = obs_metrics.Registry()
    with tempfile.TemporaryDirectory() as d:
        chaos = ChaosEngine.parse(
            "kill@3,data_corrupt@2,straggler@2:30,ckpt_corrupt@4,"
            "nonfinite@4", seed=0)
        tr = Trainer(_tc(d, steps=6, ckpt_every=2), chaos=chaos,
                     max_skips=1, obs=reg)
        with pytest.raises(SystemExit) as ei:
            tr.run()
        assert ei.value.code == 43
        # relaunch with the SAME chaos engine (fire-once: kill is spent);
        # ckpt_corrupt@4 then trashes the newest checkpoint right before
        # nonfinite@4 forces a rollback — the rollback must fall back
        # past the damage to the prior intact step
        with pytest.warns(UserWarning, match="corrupt"):
            tr2 = Trainer(_tc(d, steps=6, ckpt_every=2), chaos=chaos,
                          max_skips=1, obs=reg)
            state = tr2.run()
        assert state.step == 6
        assert np.isfinite(tr2.metrics_history[-1]["loss"])
        snap = reg.snapshot()
        for kind in ("kill", "data_corrupt", "straggler", "ckpt_corrupt",
                     "nonfinite"):
            key = f"resilience.faults_injected{{kind={kind}}}"
            assert snap[key]["value"] >= 1, key
        assert snap["resilience.rollbacks"]["value"] >= 1
        assert snap["resilience.nonfinite_steps"]["value"] >= 1
        assert snap["resilience.bad_batches"]["value"] >= 1
