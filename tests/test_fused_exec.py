"""End-to-end tests for ``exec_mode="fused"`` (ISSUE 3): config →
Builder.linear → apply_linear → core.sltrain → Pallas custom-VJP kernels,
plus the kernel-wrapper bug-batch regressions (bf16 dv accumulation,
deterministic tile capacity, blocked support sampling)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.core import sltrain, support
from repro.data.pipeline import SyntheticC4
from repro.kernels import ops
from repro.models import registry
from repro.optim import optimizers
from repro.train import step as step_lib


def _fused_smoke_cfg(dtype="float32"):
    base = registry.get_smoke_config("llama_60m")
    return dataclasses.replace(
        base, dtype=dtype,
        param=dataclasses.replace(base.param, mode="sltrain",
                                  exec_mode="fused"))


# ---------------------------------------------------------------------------
# Acceptance: token-for-token train parity with the densify path
# ---------------------------------------------------------------------------

def _run_training(cfg, steps):
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(42), seed=42)
    opt = optimizers.make(OptimizerConfig(lr=1e-3, warmup_steps=2,
                                          total_steps=steps))
    opt_state = opt.init(params)
    fn = jax.jit(step_lib.make_train_step(cfg, api, opt))
    data = SyntheticC4(cfg.vocab_size, 32, 4, seed=0)
    losses = []
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, metrics = fn(params, opt_state, consts, batch)
        losses.append(float(metrics["loss"]))
    return np.asarray(losses)


def test_fused_trains_to_loss_parity_with_dense():
    """Same seed, same data, 20 steps: the fused Pallas path must track the
    densify path token for token — a few f32 ulp of loss, every step."""
    steps = 20
    cfg_f = _fused_smoke_cfg()
    cfg_d = dataclasses.replace(
        cfg_f, param=dataclasses.replace(cfg_f.param, exec_mode="dense"))
    loss_d = _run_training(cfg_d, steps)
    loss_f = _run_training(cfg_f, steps)
    # ulp(loss≈7) in f32 is ~4.8e-7; allow a handful per step
    np.testing.assert_allclose(loss_f, loss_d, rtol=0, atol=5e-6)


# ---------------------------------------------------------------------------
# Acceptance: abstract dry-run twin matches concrete init exactly
# ---------------------------------------------------------------------------

def test_fused_abstract_init_matches_concrete_shapes():
    """The no-alloc dry-run must build fused-mode trees (including the
    layer-stacked tile consts) whose shapes/dtypes exactly match concrete
    init — this is what the deterministic tile_cap buys."""
    cfg = _fused_smoke_cfg()
    api = registry.get_api(cfg)
    params_c, consts_c = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    params_a, consts_a = api.init(cfg, key=None)

    def check(c, a):
        assert tuple(c.shape) == tuple(a.shape), (c.shape, a.shape)
        assert jnp.dtype(c.dtype) == jnp.dtype(a.dtype)

    jax.tree.map(check, params_c, params_a)
    jax.tree.map(check, consts_c, consts_a)
    # and the fused consts are actually there
    flat = jax.tree_util.tree_flatten_with_path(consts_a)[0]
    names = {str(getattr(p[-1], "key", p[-1])) for p, _ in flat}
    assert {"rows_t", "cols_t", "perm"} <= names


def test_fused_params_identical_to_dense_params():
    """exec_mode changes execution, not state: the trainable tree (and the
    sampled support) must be identical to a dense-mode init with the same
    seed — checkpoints/optimizer state stay layout-independent."""
    cfg_f = _fused_smoke_cfg()
    cfg_d = dataclasses.replace(
        cfg_f, param=dataclasses.replace(cfg_f.param, exec_mode="dense"))
    api = registry.get_api(cfg_f)
    params_f, consts_f = api.init(cfg_f, jax.random.PRNGKey(1), seed=1)
    params_d, consts_d = api.init(cfg_d, jax.random.PRNGKey(1), seed=1)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params_f, params_d)
    # dense consts (cols) are a subtree of the fused consts
    flat_d = {tuple(str(getattr(k, "key", k)) for k in p): l for p, l in
              jax.tree_util.tree_flatten_with_path(consts_d)[0]}
    flat_f = {tuple(str(getattr(k, "key", k)) for k in p): l for p, l in
              jax.tree_util.tree_flatten_with_path(consts_f)[0]}
    for path, leaf in flat_d.items():
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.asarray(flat_f[path]))


# ---------------------------------------------------------------------------
# Satellite: bf16 dv must accumulate in f32 (fused == dense gather grad)
# ---------------------------------------------------------------------------

def test_fused_dv_bf16_matches_dense_take_along_axis_grad():
    d_in, d_out, r, m = 256, 384, 16, 96
    params, consts = sltrain.init_params(
        jax.random.PRNGKey(3), d_in, d_out, r, 0.03, jnp.bfloat16,
        "row_balanced", seed=11, exec_mode="fused")
    params["B"] = (jax.random.normal(jax.random.PRNGKey(4),
                                     params["B"].shape) * 0.1
                   ).astype(jnp.bfloat16)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((m, d_in)), jnp.bfloat16)
    # f32 cotangent on purpose: upstream (norm/softmax bwd) hands f32, the
    # wrapper must align dtypes rather than crash or round-trip through bf16
    dy = jnp.asarray(rng.standard_normal((m, d_out)), jnp.float32)

    def loss(p, mode):
        return jnp.sum(sltrain.sl_matmul(x, p, consts, 0.5, mode)
                       .astype(jnp.float32) * dy)

    gd = jax.grad(lambda p: loss(p, "dense"))(params)
    gf = jax.grad(lambda p: loss(p, "fused"))(params)
    # both sides accumulate the token contraction in f32 and round ONCE to
    # bf16 — they must agree to ~1 bf16 ulp, not bf16 drift
    dv_d = np.asarray(gd["v"], np.float32)
    dv_f = np.asarray(gf["v"], np.float32)
    scale_ref = np.abs(dv_d).max()
    np.testing.assert_allclose(dv_f, dv_d, rtol=1e-2,
                               atol=1e-2 * scale_ref)


# ---------------------------------------------------------------------------
# Satellite: deterministic tile capacity + host re-sample fallback
# ---------------------------------------------------------------------------

def test_tile_layout_fixed_pad_raises_on_overflow():
    rows, cols = support.sample_support(0, 256, 256, 0.05, "row_balanced")
    with pytest.raises(ValueError, match="re-sample"):
        support.tile_layout(rows, cols, 256, 256, pad=8)


def test_tile_cap_bounds_realized_max():
    for seed in range(5):
        for (d_in, d_out, delta) in [(64, 96, 0.05), (300, 200, 0.03),
                                     (512, 128, 0.1)]:
            rows, cols = support.sample_support(seed, d_in, d_out, delta,
                                                "row_balanced")
            cap = support.tile_cap(d_in, d_out, delta)
            kp = ((d_in + 127) // 128) * 128
            np_ = ((d_out + 127) // 128) * 128
            _, _, counts, _ = support.tile_layout(rows, cols, kp, np_)
            assert int(counts.max()) <= cap, (d_in, d_out, delta, seed)


def test_fused_init_resample_fallback_raises_loudly(monkeypatch):
    """When the deterministic bound is (artificially) impossible, init must
    re-sample deterministically and then fail loudly, not loop forever or
    emit ragged consts."""
    monkeypatch.setattr(support, "tile_cap", lambda *a, **k: 8)
    with pytest.raises(ValueError, match="re-samples"):
        sltrain.init_params(jax.random.PRNGKey(0), 256, 256, 8, 0.05,
                            jnp.float32, "row_balanced", seed=0,
                            exec_mode="fused")


def test_fused_without_tile_consts_raises():
    params, consts = sltrain.init_params(
        jax.random.PRNGKey(0), 64, 64, 4, 0.05, jnp.float32, seed=0)
    x = jnp.zeros((2, 64), jnp.float32)
    with pytest.raises(ValueError, match="fused"):
        sltrain.sl_matmul(x, params, consts, 0.5, exec_mode="fused")


# ---------------------------------------------------------------------------
# Satellite: blocked support sampler agrees with the dense-keys branch
# ---------------------------------------------------------------------------

def test_sample_support_blocked_branch_matches_dense_branch(monkeypatch):
    """The row-blocked large-matrix fallback must produce the exact support
    of the full-key-matrix branch (same PRNG stream) — shrink the
    threshold so a small shape straddles it."""
    d_in, d_out, delta = 96, 130, 0.05
    full_r, full_c = support.sample_support(7, d_in, d_out, delta,
                                            "row_balanced")
    # force the blocked branch: threshold below d_in*d_out but above d_out
    monkeypatch.setattr(support, "DENSE_KEYS_ELEMS", 4 * d_out)
    blk_r, blk_c = support.sample_support(7, d_in, d_out, delta,
                                          "row_balanced")
    np.testing.assert_array_equal(full_r, blk_r)
    np.testing.assert_array_equal(full_c, blk_c)


# ---------------------------------------------------------------------------
# Sharding specs + modeled HBM
# ---------------------------------------------------------------------------

def test_fused_tile_consts_shard_nnt_over_model():
    """ISSUE 8: tile consts shard their nnt (d_out-tile) axis over the
    model axis — the same layout as A's d_out — so the distributed fused
    vjp reads only local column tiles; every other dim (layer stack, nkt,
    cap) stays replicated, and a non-dividing nnt replicates entirely."""
    from jax.sharding import PartitionSpec as P

    from repro.dist import sharding as shl
    mesh = shl.make_local_mesh()
    cfg = _fused_smoke_cfg()
    _, consts_abs = registry.get_api(cfg).init(cfg, key=None)
    specs = shl.param_specs(consts_abs, mesh)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    seen = set()
    for path, spec in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        if name in ("rows_t", "cols_t", "perm"):
            seen.add(name)
            # spec covers (…stack, nkt, nnt, cap): only nnt carries model
            assert spec[-2] in (("model",), None), (path, spec)
            assert all(s is None for i, s in enumerate(spec)
                       if i != len(spec) - 2), (path, spec)
    assert seen == {"rows_t", "cols_t", "perm"}

    class _TPMesh:  # spec logic only reads axis_names/shape
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 7}   # 7 never divides nnt

    specs7 = shl.param_specs(consts_abs, _TPMesh())
    for path, spec in jax.tree_util.tree_flatten_with_path(
            specs7, is_leaf=lambda x: isinstance(x, P))[0]:
        name = str(getattr(path[-1], "key", path[-1]))
        if name in ("rows_t", "cols_t", "perm"):
            assert all(s is None for s in spec), (path, spec)


def test_modeled_hbm_fused_beats_densify_by_compression():
    """Acceptance: the fused train step's modeled parameter HBM traffic
    beats the densify path by at least the paper's compression ratio."""
    from benchmarks.kernel_bench import _sltrain_traffic_model
    cfg = _fused_smoke_cfg()
    params_abs, consts_abs = registry.get_api(cfg).init(cfg, key=None)
    densify, fused, compression = _sltrain_traffic_model(params_abs,
                                                         consts_abs)
    assert compression > 1.0
    assert densify / fused >= compression, (densify, fused, compression)
