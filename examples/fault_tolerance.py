"""Fault-tolerance demo (DESIGN §7): kill the trainer mid-run, relaunch,
and verify the final parameters are BIT-EXACT with an uninterrupted run.

Exercises: atomic checkpointing, data-pipeline cursor restore, config-hash
validation, and the resume-from-latest launcher contract.

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import tempfile

import jax
import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, ParamConfig,
                                TrainConfig)
from repro.train.trainer import Trainer

cfg = ModelConfig(
    name="ft-demo", family="llama",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab_size=512, vocab_pad_multiple=64, max_seq_len=64,
    tie_embeddings=False,
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=16.0),
)


def make_tc(ckpt_dir):
    return TrainConfig(
        model=cfg,
        optim=OptimizerConfig(lr=1e-3, warmup_steps=4, total_steps=40),
        global_batch=4, seq_len=64, steps=40, log_every=10, ckpt_every=10,
        ckpt_dir=ckpt_dir, async_ckpt=True)


class SimulatedPreemption(Exception):
    pass


if __name__ == "__main__":
    ref_dir = tempfile.mkdtemp(prefix="ft_ref_")
    crash_dir = tempfile.mkdtemp(prefix="ft_crash_")

    print("== reference run (no faults) ==")
    ref = Trainer(make_tc(ref_dir)).run()

    print("\n== faulty run: SIGKILL simulation at step 17 ==")
    crashed = {"done": False}

    def fault(step):
        if step == 17 and not crashed["done"]:
            crashed["done"] = True
            raise SimulatedPreemption("node died")

    try:
        Trainer(make_tc(crash_dir), fault_hook=fault).run()
    except SimulatedPreemption as e:
        print(f"  !! trainer killed: {e}")

    print("\n== relaunch (same command, resumes from latest checkpoint) ==")
    resumed = Trainer(make_tc(crash_dir)).run()

    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(ref.params)[0],
            jax.tree_util.tree_flatten_with_path(resumed.params)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("\nOK: resumed run is BIT-EXACT with the uninterrupted run "
          f"({len(jax.tree.leaves(ref.params))} parameter leaves compared).")
