"""Serving example: continuous batching + the SLTrain sparse-decode mode.

Trains a tiny SLTrain model briefly so the weights are non-trivial, then
serves a mixed batch of requests twice — once with the standard densify
decode and once with the beyond-paper factored ``sparse`` execution mode
(DESIGN §3) — and verifies they emit identical tokens while the sparse
mode reads ~2-3× fewer parameter bytes per step.

  PYTHONPATH=src python examples/serve_batched.py
"""
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, ParamConfig,
                                TrainConfig)
from repro.core import sltrain
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer

cfg = ModelConfig(
    name="serve-demo", family="llama",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=320,
    vocab_size=2048, vocab_pad_multiple=64, max_seq_len=128,
    tie_embeddings=False,
    param=ParamConfig(mode="sltrain", rank=16, delta=0.05, alpha=16.0),
)

if __name__ == "__main__":
    tc = TrainConfig(model=cfg,
                     optim=OptimizerConfig(lr=3e-3, warmup_steps=10,
                                           total_steps=100),
                     global_batch=8, seq_len=64, steps=100, log_every=50,
                     ckpt_every=0, ckpt_dir=tempfile.mkdtemp())
    trainer = Trainer(tc)
    state = trainer.run()

    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, cfg.vocab_size, size=int(rng.integers(2, 8))
                            ).tolist() for _ in range(6)]
    outs = {}
    for sparse in (False, True):
        eng = ServeEngine(cfg, state.params, state.consts, n_slots=3,
                          max_len=64, sparse_decode=sparse)
        reqs = [eng.submit(p, max_new_tokens=12) for p in prompts]
        t0 = time.perf_counter()
        stats = eng.run_until_drained()
        dt = time.perf_counter() - t0
        outs[sparse] = [r.out for r in reqs]
        label = "sparse" if sparse else "dense "
        total = sum(len(r.out) for r in reqs)
        print(f"[{label}] {total} tokens in {dt:.2f}s "
              f"({stats['decode_steps']} batched decode steps)")
    assert outs[False] == outs[True], "sparse decode diverged from dense!"
    # parameter-byte accounting per decode step (the decode roofline win)
    d, f = cfg.d_model, cfg.d_ff
    dense_bytes = sum(2 * a * b for a, b in
                      [(d, d)] * 4 + [(d, f)] * 2 + [(f, d)])
    r = cfg.param.rank
    tr_, nnz = sltrain.param_count(d, d, r, cfg.param.delta)
    print(f"\nOK: identical tokens. SLTrain factored decode reads "
          f"{tr_ * 2}B per d×d matrix vs {2 * d * d}B densified "
          f"({2 * d * d / (tr_ * 2):.1f}x less HBM traffic per step).")
