"""Serving example: continuous batching, the SLTrain sparse-decode mode,
the paged KV cache, and the paged-attention decode kernel.

Trains a tiny SLTrain model briefly so the weights are non-trivial, then
serves a mixed batch of requests four ways — legacy contiguous cache and
block-paged cache, each with the standard densify decode and the
beyond-paper factored ``sparse`` execution mode (DESIGN §3). Sparse must
match dense token-for-token on both layouts, and the paged engine must
match single-request ground truth exactly (the legacy engine generally
does not on mixed-length batches — its shared max(pos) write index is the
wart the paged per-slot positions remove). The sparse mode reads ~2-3×
fewer parameter bytes per step; the paged engine additionally prefills
each prompt in ONE jit dispatch (legacy: one per prompt token).

Finally the same workload runs with ``attn_kernel="paged"``: decode
attends the block pools in place through the Pallas paged-attention
kernel (kernels/paged_attention.py) instead of materializing the gathered
per-slot K/V view — identical tokens, with per-layer decode HBM K/V
traffic tracking live tokens instead of n_slots × view_len (the engine's
``kv_traffic`` counters model both). A final pass serves Poisson arrivals
through ``run_stream`` (continuous batching) with copy-on-write prefix
sharing: prompts opening with a resident block-aligned prefix attach
those pages read-only and prefill only the suffix, still token-for-token
identical to single-request ground truth. A closing section calibrates
the trained weights to int8 (repro.quant) and serves the same prompts
through ``exec_mode="quant"``, printing the bf16-vs-int8 modeled
sparse-term decode bytes.

  PYTHONPATH=src python examples/serve_batched.py
"""
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, ParamConfig,
                                TrainConfig)
from repro.core import sltrain
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer

cfg = ModelConfig(
    name="serve-demo", family="llama",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=320,
    vocab_size=2048, vocab_pad_multiple=64, max_seq_len=128,
    tie_embeddings=False,
    param=ParamConfig(mode="sltrain", rank=16, delta=0.05, alpha=16.0),
)

if __name__ == "__main__":
    tc = TrainConfig(model=cfg,
                     optim=OptimizerConfig(lr=3e-3, warmup_steps=10,
                                           total_steps=100),
                     global_batch=8, seq_len=64, steps=100, log_every=50,
                     ckpt_every=0, ckpt_dir=tempfile.mkdtemp())
    trainer = Trainer(tc)
    state = trainer.run()

    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, cfg.vocab_size, size=int(rng.integers(2, 8))
                            ).tolist() for _ in range(6)]
    outs = {}
    for paged in (False, True):
        for sparse in (False, True):
            # sparse==dense parity is pinned on the gather read path so
            # both modes share attention numerics exactly; the paged
            # kernel (different softmax accumulation order — can flip
            # near-tied argmaxes of this tiny model) gets its own
            # ground-truth comparison below
            eng = ServeEngine(cfg, state.params, state.consts, n_slots=3,
                              max_len=64, sparse_decode=sparse, paged=paged,
                              block_len=8,
                              attn_kernel="gather" if paged else None)
            reqs = [eng.submit(p, max_new_tokens=12) for p in prompts]
            t0 = time.perf_counter()
            stats = eng.run_until_drained()
            dt = time.perf_counter() - t0
            outs[(paged, sparse)] = [r.out for r in reqs]
            label = (("paged " if paged else "legacy") + "/" +
                     ("sparse" if sparse else "dense "))
            total = sum(len(r.out) for r in reqs)
            print(f"[{label}] {total} tokens in {dt:.2f}s "
                  f"({stats['decode_steps']} decode steps, "
                  f"{eng.dispatches['prefill']} prefill dispatches, "
                  f"{len(stats['completed'])} completed)")
    # sparse decode must be byte-identical to dense on either cache layout
    assert outs[(False, False)] == outs[(False, True)], "legacy sparse diverged!"
    # ... away from EXACT argmax ties, which this tiny 100-step model has:
    # one request's dense logits hit a top-2 gap of exactly 0.0 in f32
    # mid-decode, so the ~1e-6 sparse-numerics difference legally breaks
    # the tie the other way and the greedy streams fork from there. The
    # engine contract is pinned exactly in tier-1
    # (test_paged_sparse_decode_matches_dense); the demo tolerates one
    # tie-forked request.
    n_ps = sum(a == b for a, b in zip(outs[(True, False)], outs[(True, True)]))
    assert n_ps >= len(prompts) - 1, \
        f"paged sparse diverged on {len(prompts) - n_ps} requests"
    # ground truth = each request served alone (no slot interference); the
    # paged engine must reproduce it exactly even in a mixed-length batch.
    # The legacy engine generally does NOT (its single shared max(pos)
    # write index corrupts lagging slots — the wart the paged per-slot
    # index vector removes), so it is not held to this bar.
    truth = []
    eng = ServeEngine(cfg, state.params, state.consts, n_slots=1, max_len=64)
    for p in prompts:             # one engine, drained between submits
        r = eng.submit(p, max_new_tokens=12)
        eng.run_until_drained()
        truth.append(r.out)
    assert outs[(True, False)] == truth, "paged diverged from single-request!"
    n_legacy_ok = sum(a == b for a, b in zip(outs[(False, False)], truth))
    print(f"legacy matches single-request ground truth on "
          f"{n_legacy_ok}/{len(truth)} requests (shared-index wart); "
          f"paged on {len(truth)}/{len(truth)}")
    # paged-attention kernel: same tokens, no gathered view — decode K/V
    # traffic tracks live tokens instead of n_slots × view_len
    eng = ServeEngine(cfg, state.params, state.consts, n_slots=3,
                      max_len=64, paged=True, block_len=8,
                      attn_kernel="paged")
    reqs = [eng.submit(p, max_new_tokens=12) for p in prompts]
    eng.run_until_drained()
    assert [r.out for r in reqs] == truth, "paged kernel diverged!"
    t = eng.kv_traffic
    print(f"[paged /kernel] tokens match ground truth; modeled decode K/V "
          f"reads: {t['live_tokens']} live vs {t['gather_tokens']} "
          f"gathered rows over {t['steps']} steps "
          f"({t['gather_tokens']/max(t['live_tokens'],1):.1f}x less HBM "
          f"K/V traffic per step)")
    # continuous batching + copy-on-write prefix sharing: requests arrive
    # on a Poisson clock and are admitted into freed slots mid-decode by
    # run_stream; prompts opening with a resident block-aligned prefix
    # attach those pages read-only (refcount++) and prefill only the
    # suffix. Tokens must still match per-request ground truth exactly.
    shared = rng.integers(3, cfg.vocab_size, size=16).tolist()
    sprompts = [shared + rng.integers(3, cfg.vocab_size,
                                      size=int(rng.integers(2, 6))).tolist()
                for _ in range(6)]
    struth = []
    eng = ServeEngine(cfg, state.params, state.consts, n_slots=1, max_len=64)
    for p in sprompts:
        r = eng.submit(p, max_new_tokens=12)
        eng.run_until_drained()
        struth.append(r.out)
    eng = ServeEngine(cfg, state.params, state.consts, n_slots=3,
                      max_len=64, paged=True, block_len=8,
                      prefix_sharing=True)
    arrivals = np.cumsum(rng.poisson(2.0, size=len(sprompts)))
    reqs = [eng.submit(p, max_new_tokens=12, arrival=int(a))
            for p, a in zip(sprompts, arrivals)]
    stats = eng.run_stream()
    assert [r.out for r in reqs] == struth, "stream+shared diverged!"
    pt = eng.prefill_traffic
    ttft = sorted(r.t_first - r.arrival for r in reqs)
    print(f"[stream/shared] tokens match ground truth; "
          f"{pt['tokens_shared']}/{pt['tokens_total']} prompt tokens "
          f"attached from resident pages (prefilled only "
          f"{pt['tokens_prefilled']}); TTFT ticks p50={ttft[len(ttft)//2]} "
          f"max={ttft[-1]} over {stats['decode_steps']} decode steps")
    # quantized decode (repro.quant): one-shot int8 calibration of the
    # trained weights, served through the fused quant kernel — greedy
    # tokens should track the bf16 sparse path (int8 can legally flip a
    # near-tied argmax on a model this small, so count matches rather
    # than hard-assert), while the sparse term's modeled decode bytes
    # drop 12 B/nnz -> 5 B/nnz (+ per-channel scales)
    from repro.quant import calibrate, layout
    qp, qc, qstats = calibrate.calibrate_model(cfg, state.params,
                                               state.consts)
    eng = ServeEngine(cfg, qp, qc, n_slots=3, max_len=64, paged=True,
                      block_len=8, attn_kernel="gather", exec_mode="quant")
    reqs = [eng.submit(p, max_new_tokens=12) for p in prompts]
    eng.run_until_drained()
    tok_pairs = [(a, b) for r, t in zip(reqs, truth)
                 for a, b in zip(r.out, t)]
    n_tok = sum(a == b for a, b in tok_pairs)
    n_q = sum(r.out == t for r, t in zip(reqs, truth))
    print(f"[paged /quant ] int8 decode matches ground truth on "
          f"{n_q}/{len(truth)} requests, {n_tok}/{len(tok_pairs)} tokens "
          f"({qstats['n_matrices']} matrices calibrated, max |W-Wq| = "
          f"{qstats['max_abs_err']:.1e})")
    assert n_tok >= 0.75 * len(tok_pairs), \
        f"quant decode matched only {n_tok}/{len(tok_pairs)} greedy tokens"
    qb = {q: layout.sparse_decode_bytes(d_ := cfg.d_model, d_,
                                        cfg.param.delta,
                                        cfg.param.support_kind, quant=q)
          for q in (False, True)}
    print(f"[paged /quant ] modeled sparse-term decode bytes per d×d "
          f"matrix: {qb[False]}B bf16 -> {qb[True]}B int8 "
          f"({qb[False]/qb[True]:.1f}x less)")
    # parameter-byte accounting per decode step (the decode roofline win)
    d, f = cfg.d_model, cfg.d_ff
    dense_bytes = sum(2 * a * b for a, b in
                      [(d, d)] * 4 + [(d, f)] * 2 + [(f, d)])
    r = cfg.param.rank
    tr_, nnz = sltrain.param_count(d, d, r, cfg.param.delta)
    print(f"\nOK: sparse==dense (away from exact ties); "
          f"paged==single-request; stream+shared==single-request. "
          f"SLTrain factored decode reads {tr_ * 2}B per d×d matrix vs "
          f"{2 * d * d}B densified "
          f"({2 * d * d / (tr_ * 2):.1f}x less HBM traffic per step).")
