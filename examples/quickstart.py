"""Quickstart: pretrain a small LLaMA with SLTrain on synthetic C4 (CPU).

Shows the public API end-to-end: config → model → SLTrain parameterization
→ optimizer → trainer → checkpoint → eval. Takes ~1 minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import tempfile

import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, ParamConfig,
                                TrainConfig)
from repro.data.pipeline import unigram_entropy
from repro.train.trainer import Trainer

# A ~1M-param LLaMA with the paper's parameterization: every linear is
# W = (α/r)·B·A ⊕_I V with fixed random support (δ=0.05).
cfg = ModelConfig(
    name="quickstart-llama",
    family="llama",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=320,
    vocab_size=2048, vocab_pad_multiple=64, max_seq_len=128,
    tie_embeddings=False,
    param=ParamConfig(mode="sltrain", rank=16, delta=0.05, alpha=16.0),
)

tc = TrainConfig(
    model=cfg,
    optim=OptimizerConfig(lr=3e-3, warmup_steps=30, total_steps=300),
    global_batch=8, seq_len=128, steps=300, log_every=50,
    ckpt_every=150, ckpt_dir=tempfile.mkdtemp(prefix="quickstart_ckpt_"),
)

if __name__ == "__main__":
    h_unigram = unigram_entropy(cfg.vocab_size)
    print(f"synthetic-C4 unigram entropy (no-learning bound): "
          f"{h_unigram:.3f} nats")
    trainer = Trainer(tc)
    state = trainer.run()
    losses = [m["loss"] for m in trainer.metrics_history]
    print(f"\nloss: {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"(unigram bound {h_unigram:.3f})")
    assert np.mean(losses[-10:]) < h_unigram, \
        "model failed to learn beyond unigram statistics"
    n_train = sum(x.size for x in __import__("jax").tree.leaves(state.params))
    print(f"trainable params: {n_train/1e6:.2f}M  "
          f"(checkpoints in {tc.ckpt_dir})")
    print("OK: SLTrain learned the Markov structure of the corpus.")
