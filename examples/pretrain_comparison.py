"""End-to-end driver (deliverable (b)): pretrain the same LLaMA with the
paper's four parameterizations — Full-Rank, Low-Rank, SLTrain, ReLoRA — at
an equal token budget, and reproduce the paper's qualitative Table 2
ordering: full ≈ sltrain < relora < lowrank (lower PPL better).

~4-8 minutes on CPU at the default scale; pass --steps/--dim to scale up
(the same script drives the full 60M-7B runs on real hardware via
--size 60m/130m/... which swaps in the paper's exact configs).

  PYTHONPATH=src python examples/pretrain_comparison.py --steps 300
"""
import argparse
import dataclasses
import json
import tempfile

import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, ParamConfig,
                                TrainConfig)
from repro.models import registry
from repro.train.trainer import Trainer


def base_config(dim: int) -> ModelConfig:
    return ModelConfig(
        name="compare-llama",
        family="llama",
        n_layers=2, d_model=dim, n_heads=4, n_kv_heads=4,
        d_ff=int(dim * 2.5), vocab_size=2048, vocab_pad_multiple=64,
        max_seq_len=128, tie_embeddings=False,
        param=ParamConfig(rank=max(8, dim // 8), delta=0.05, alpha=16.0),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--size", default=None,
                    help="paper size (60m/130m/350m/1b/7b) instead of --dim")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = {}
    for mode in ("dense", "sltrain", "relora", "lowrank"):
        if args.size:
            cfg = registry.get_config(f"llama_{args.size}")
        else:
            cfg = base_config(args.dim)
        cfg = dataclasses.replace(
            cfg, param=dataclasses.replace(cfg.param, mode=mode))
        tc = TrainConfig(
            model=cfg,
            optim=OptimizerConfig(lr=3e-3, warmup_steps=args.steps // 10,
                                  total_steps=args.steps),
            global_batch=args.batch, seq_len=args.seq, steps=args.steps,
            log_every=max(50, args.steps // 4), ckpt_every=0,
            ckpt_dir=tempfile.mkdtemp(prefix=f"cmp_{mode}_"))
        print(f"=== {mode} ===")
        tr = Trainer(tc)
        state = tr.run()
        import jax
        n = sum(x.size for x in jax.tree.leaves(state.params))
        loss = float(np.mean([m["loss"] for m in tr.metrics_history[-10:]]))
        results[mode] = {"loss": loss, "ppl": float(np.exp(loss)),
                         "params_M": n / 1e6,
                         "s_per_step": float(np.median(
                             [m["dt"] for m in tr.metrics_history]))}

    print(f"\n{'method':10s} {'PPL':>9s} {'params(M)':>10s} {'s/step':>8s}")
    for mode, r in sorted(results.items(), key=lambda kv: kv[1]["ppl"]):
        print(f"{mode:10s} {r['ppl']:9.2f} {r['params_M']:10.2f} "
              f"{r['s_per_step']:8.3f}")
    # paper's qualitative ordering at equal tokens
    assert results["sltrain"]["ppl"] < results["lowrank"]["ppl"], \
        "SLTrain should beat pure low-rank (paper Table 2)"
    assert results["sltrain"]["params_M"] < results["dense"]["params_M"], \
        "SLTrain should be parameter-efficient vs full-rank"
    print("\nOK: SLTrain < Low-Rank in PPL at fewer params than Full-Rank.")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
