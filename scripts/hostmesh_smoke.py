"""2-device host-mesh smoke (ISSUE 8 tentpole d).

Forces ``xla_force_host_platform_device_count=2`` and validates the
distributed paths a single-device CI never exercises:

  * ``wire``     — lowers the int8-compressed DP train step on a
    (pod=2) mesh and checks the ``dist/compression.wire_bytes`` analytic
    model against the collective bytes MEASURED from the compiled
    post-SPMD HLO (analysis/roofline.parse_collectives). Prints
    ``wire_model_ratio=<measured/modeled>``; asserts it lands within
    ring-algorithm tolerance.
  * ``dp``       — executes 3 compressed-DP steps end-to-end (finite
    losses, obs ``dist.collective_bytes`` counters populated, both
    compression labels present).
  * ``perlayer`` — per_layer + grad_accum=2 vs global + grad_accum=2,
    token-for-token over 3 steps, on a (data=2, model=1) mesh with the
    batch sharded over data.
  * ``fused``    — the distributed fused backward island
    (kernels/ops._fused_grads_dist) engages on a (data=1, model=2) mesh
    and its gradients match the local fused path.

Usage:
  python scripts/hostmesh_smoke.py            # all parts
  python scripts/hostmesh_smoke.py --part wire
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=2").strip()
# ^ must precede jax import: device count locks at first backend init.
import argparse
import dataclasses
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as roofline_lib
from repro.configs.base import OptimizerConfig
from repro.data.pipeline import SyntheticC4
from repro.dist import compat, compression
from repro.models import registry
from repro.obs import metrics as obs_metrics
from repro.optim import optimizers
from repro.train import perlayer, step as step_lib


def _smoke_cfg(exec_mode="dense"):
    base = registry.get_smoke_config("llama_60m")
    return dataclasses.replace(
        base, dtype="float32",
        param=dataclasses.replace(base.param, mode="sltrain",
                                  exec_mode=exec_mode))


def _state(cfg, steps=10):
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    opt = optimizers.make(OptimizerConfig(lr=1e-3, warmup_steps=2,
                                          total_steps=steps))
    return api, params, consts, opt, opt.init(params)


def _batches(cfg, n, batch=4, seq=32):
    data = SyntheticC4(cfg.vocab_size, seq, batch, seed=0)
    return [{k: jnp.asarray(v) for k, v in data.next_batch().items()}
            for _ in range(n)]


def _pod_mesh():
    return compat.make_mesh((2,), ("pod",),
                            axis_types=(compat.AxisType.Auto,))


def smoke_wire_model():
    """Model-vs-HLO: the wire_bytes analytic model must agree with the
    collectives XLA actually emits for the compressed-DP step."""
    cfg = _smoke_cfg()
    api, params, consts, opt, opt_state = _state(cfg)
    mesh = _pod_mesh()
    step = step_lib.make_compressed_dp_step(cfg, api, opt, mesh)
    batch = _batches(cfg, 1)[0]
    compiled = jax.jit(step).lower(params, opt_state, consts, batch).compile()
    stats = roofline_lib.parse_collectives(compiled.as_text())
    measured = stats.total_wire_bytes

    modeled = 0.0
    for g in jax.tree.leaves(params):   # grads mirror the param tree
        comp = (jnp.issubdtype(g.dtype, jnp.floating) and g.size >= 1024)
        modeled += 2 * compression.wire_bytes(
            g.size, compressed=comp, n_participants=2,
            dtype_bytes=4 if comp else jnp.dtype(g.dtype).itemsize)

    ratio = measured / modeled
    print(f"hostmesh_smoke[wire]: HLO measured {measured / 1e6:.3f} MB "
          f"vs model {modeled / 1e6:.3f} MB  wire_model_ratio={ratio:.4f}")
    print(f"hostmesh_smoke[wire]: collective counts {stats.counts}")
    # the model omits XLA's scale-sync return traffic and fusion-combined
    # residue; ring-algorithm tolerance per the ISSUE-8 acceptance bar
    assert 0.7 <= ratio <= 1.3, (
        f"wire model diverged from HLO-measured collectives: ratio {ratio} "
        f"(measured {measured}, modeled {modeled})")


def smoke_compressed_dp():
    """3 end-to-end int8-compressed DP steps on the 2-pod host mesh."""
    cfg = _smoke_cfg()
    api, params, consts, opt, opt_state = _state(cfg)
    mesh = _pod_mesh()
    reg = obs_metrics.Registry()
    step = jax.jit(step_lib.make_compressed_dp_step(cfg, api, opt, mesh,
                                                    obs=reg))
    losses = []
    for batch in _batches(cfg, 3):
        params, opt_state, m = step(params, opt_state, consts, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    snap = reg.snapshot()
    ct = snap.get("dist.collective_bytes{compressed=true}", {}).get("value", 0)
    cf = snap.get("dist.collective_bytes{compressed=false}", {}).get("value", 0)
    assert ct > 0 and cf > 0, snap
    print(f"hostmesh_smoke[dp]: losses {['%.4f' % l for l in losses]}  "
          f"collective_bytes compressed={ct} uncompressed={cf}")


def smoke_perlayer_grad_accum():
    """per_layer + grad_accum=2 == global + grad_accum=2 on a data-sharded
    2-device mesh, 3 steps token for token."""
    mesh = compat.make_mesh((2, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    cfg = _smoke_cfg()
    api, params, consts, opt, opt_state = _state(cfg)
    g_step = jax.jit(step_lib.make_train_step(cfg, api, opt, grad_accum=2))
    p_step = jax.jit(perlayer.make_perlayer_train_step(cfg, api, opt,
                                                       grad_accum=2))
    rep = NamedSharding(mesh, P())
    sh_batch = lambda b: jax.device_put(
        b, NamedSharding(mesh, P("data", None)))
    pg = jax.device_put(params, rep)
    pp = jax.device_put(params, rep)
    og = jax.device_put(opt_state, rep)
    op = jax.device_put(opt_state, rep)
    cr = jax.device_put(consts, rep)
    with mesh:
        for i, batch in enumerate(_batches(cfg, 3)):
            batch = {k: sh_batch(v) for k, v in batch.items()}
            pg, og, mg = g_step(pg, og, cr, batch)
            pp, op, mp = p_step(pp, op, cr, batch)
            lg, lp = float(mg["loss"]), float(mp["loss"])
            print(f"hostmesh_smoke[perlayer]: step {i} global={lg:.6f} "
                  f"per_layer={lp:.6f}")
            assert abs(lg - lp) < 3e-5, (i, lg, lp)
            assert np.isfinite(lg), lg


def smoke_fused_dist():
    """kernels/ops._fused_grads_dist engages on TP=2 and matches the
    local fused backward."""
    from repro.core import sltrain
    from repro.kernels import ops

    mesh = compat.make_mesh((1, 2), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    d_in, d_out, r, delta, scale = 256, 256, 16, 0.05, 0.5
    params, consts = sltrain.init_params(
        jax.random.PRNGKey(3), d_in, d_out, r, delta, jnp.float32,
        "row_balanced", seed=11, exec_mode="fused")
    params = jax.tree.map(
        lambda t: jax.random.normal(jax.random.PRNGKey(7), t.shape,
                                    t.dtype) * 0.1, params)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 8, d_in)), jnp.float32)
    dy = jnp.asarray(rng.standard_normal((2, 8, d_out)), jnp.float32)

    # the island must actually engage under the TP mesh (geometry divides)
    v_t = ops._gather_tiles(params["v"], consts["perm"])
    with mesh:
        out = ops._fused_grads_dist(x, params["B"], params["A"], v_t,
                                    consts["rows_t"], consts["cols_t"],
                                    scale, dy)
    assert out is not None, "distributed fused island declined TP=2 geometry"

    def loss(p):
        y = sltrain.sl_matmul(x, p, consts, scale, exec_mode="fused")
        return jnp.sum(y.astype(jnp.float32) * dy)

    g_local = jax.jit(jax.grad(loss))(params)
    with mesh:
        g_dist = jax.jit(jax.grad(loss))(params)
    for key in g_local:
        a = np.asarray(g_local[key], np.float32)
        b = np.asarray(g_dist[key], np.float32)
        tol = 1e-4 * max(1.0, float(np.abs(a).max()))
        np.testing.assert_allclose(b, a, rtol=0, atol=tol, err_msg=key)
    print("hostmesh_smoke[fused]: distributed fused grads match local "
          f"path on TP=2 ({', '.join(g_local)})")


PARTS = {"wire": smoke_wire_model, "dp": smoke_compressed_dp,
         "perlayer": smoke_perlayer_grad_accum, "fused": smoke_fused_dist}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--part", choices=sorted(PARTS), default=None,
                    help="run one part (default: all)")
    args = ap.parse_args(argv)
    assert jax.device_count() == 2, (
        f"need exactly 2 host devices, got {jax.device_count()}")
    for name in ([args.part] if args.part else
                 ("wire", "dp", "perlayer", "fused")):
        PARTS[name]()
    print("hostmesh_smoke: all parts passed")


if __name__ == "__main__":
    main()
