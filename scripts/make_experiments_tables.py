"""Render §Dry-run / §Roofline markdown tables from dryrun JSONL results."""
import argparse
import json
import sys


def load(path):
    rows = []
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    return rows


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(rows, multi_pod):
    out = ["| arch | cell | chips | args GiB/dev | temp GiB/dev | "
           "collectives (AR/AG/RS/A2A/CP) | compile s |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["multi_pod"] != multi_pod:
            continue
        c = r["collectives"]["counts"]
        cc = "/".join(str(c.get(k, 0)) for k in
                      ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['chips']} | "
            f"{fmt_bytes(r['bytes_per_device']['argument'])} | "
            f"{fmt_bytes(r['bytes_per_device']['temp'])} | {cc} | "
            f"{r['compile_s']} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | cell | t_compute s | t_memory s | t_collective s | "
           "bottleneck | roofline frac | MODEL_FLOPS/HLO |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["multi_pod"]:
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['cell']} | {rl['t_compute_s']:.3f} | "
            f"{rl['t_memory_s']:.3f} | {rl['t_collective_s']:.3f} | "
            f"{rl['bottleneck']} | {rl['roofline_fraction']:.3f} | "
            f"{rl['useful_ratio']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl")
    ap.add_argument("--which", default="all",
                    choices=["all", "dryrun1", "dryrun2", "roofline"])
    a = ap.parse_args()
    rows = load(a.jsonl)
    if a.which in ("all", "dryrun1"):
        print("### single-pod (16×16 = 256 chips)\n")
        print(dryrun_table(rows, False))
    if a.which in ("all", "dryrun2"):
        print("\n### multi-pod (2×16×16 = 512 chips)\n")
        print(dryrun_table(rows, True))
    if a.which in ("all", "roofline"):
        print("\n### roofline (single-pod)\n")
        print(roofline_table(rows))
