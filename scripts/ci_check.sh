#!/usr/bin/env bash
# One-command regression gate: tier-1 tests + core smoke + a host-mesh
# dry-run through the repro.dist spec engine + a paged serve smoke.
# Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if ! python -c "import hypothesis" 2>/dev/null; then
  echo "!! NOTICE: hypothesis is not installed — property tests will run"
  echo "!! on the seeded-loop fallback in tests/_propshim.py (no shrinking,"
  echo "!! fixed examples). Install requirements-dev.txt for full coverage."
fi

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: core SLTrain invariants =="
python scripts/smoke_core.py

echo "== dry-run: llama_60m x train_4k on the 256-chip host mesh =="
python -m repro.launch.dryrun --arch llama_60m --cell train_4k

echo "== serve smoke: paged KV engine, 3 staggered requests =="
python -m repro.launch.serve --arch llama_60m --smoke --paged --block-len 8 \
  --requests 3 --stagger --slots 2 --new-tokens 4 --max-len 64

echo "ci_check: all gates passed"
