#!/usr/bin/env bash
# One-command regression gate: tier-1 tests + core smoke + a host-mesh
# dry-run through the repro.dist spec engine. Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: core SLTrain invariants =="
python scripts/smoke_core.py

echo "== dry-run: llama_60m x train_4k on the 256-chip host mesh =="
python -m repro.launch.dryrun --arch llama_60m --cell train_4k

echo "ci_check: all gates passed"
