#!/usr/bin/env bash
# One-command regression gate: tier-1 tests + core smoke + a host-mesh
# dry-run through the repro.dist spec engine + the 2-device host-mesh
# smoke (compressed-DP, per_layer x grad_accum, distributed fused) + the
# llama_7b fsdp placement gate + paged serve smokes (gathered-view and
# paged-attention-kernel decode). Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if ! python -c "import hypothesis" 2>/dev/null; then
  # try to heal the env first: when network allows, real hypothesis
  # replaces the propshim and the property tests get shrinking + fresh
  # examples. Offline (the common container case) this fails quietly and
  # the fallback notice below stands.
  pip install -q -r requirements-dev.txt 2>/dev/null || true
fi
if python -c "import hypothesis" 2>/dev/null; then
  echo "hypothesis $(python -c 'import hypothesis; print(hypothesis.__version__)') — property tests run with full shrinking (pin: requirements-dev.txt)"
else
  echo "!! NOTICE: hypothesis is not installed — property tests will run"
  echo "!! on the seeded-loop fallback in tests/_propshim.py (no shrinking,"
  echo "!! fixed examples). Install requirements-dev.txt for full coverage."
fi

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: core SLTrain invariants =="
python scripts/smoke_core.py

echo "== dry-run: llama_60m x train_4k on the 256-chip host mesh =="
python -m repro.launch.dryrun --arch llama_60m --cell train_4k

echo "== host-mesh smoke: compressed-DP + wire model, per_layer+grad_accum=2, fused TP=2 =="
python scripts/hostmesh_smoke.py

echo "== fsdp gate: llama_7b placement residency + lower on the 8-device host mesh =="
python scripts/fsdp_dryrun.py

echo "== fused smoke: exec_mode=fused 3-step train on the Pallas path =="
python -m repro.launch.train --arch llama_60m --smoke --mode sltrain \
  --exec-mode fused --steps 3 --batch 2 --seq 16 --log-every 1 \
  --ckpt-dir "$(mktemp -d)"

echo "== per-layer smoke: update_mode=per_layer 8-bit 3-step train =="
OBS_DIR="$(mktemp -d)"
python -m repro.launch.train --arch llama_60m --smoke --mode sltrain \
  --update-mode per_layer --optimizer adam8bit --steps 3 --batch 2 --seq 16 \
  --log-every 1 --ckpt-dir "$(mktemp -d)" --layer-timing \
  --metrics-out "$OBS_DIR/train.jsonl" --trace-out "$OBS_DIR/train_trace.json"

echo "== serve smoke: paged KV engine, 3 staggered requests =="
python -m repro.launch.serve --arch llama_60m --smoke --paged --block-len 8 \
  --requests 3 --stagger --slots 2 --new-tokens 4 --max-len 64

echo "== serve smoke: paged-attention kernel decode (interpret mode) =="
python -m repro.launch.serve --arch llama_60m --smoke --paged \
  --attn-kernel paged --block-len 8 --requests 3 --stagger --slots 2 \
  --new-tokens 4 --max-len 64

echo "== serve smoke: continuous batching + copy-on-write prefix sharing =="
python -m repro.launch.serve --arch llama_60m --smoke --paged --block-len 8 \
  --stream --prefix-sharing --requests 4 --slots 2 --new-tokens 4 \
  --max-len 64 --metrics-out "$OBS_DIR/serve.jsonl" \
  --trace-out "$OBS_DIR/serve_trace.json"

echo "== quant smoke: train -> calibrate -> int8 serve =="
QDIR="$(mktemp -d)"
python -m repro.launch.train --arch llama_60m --smoke --mode sltrain \
  --steps 3 --batch 2 --seq 16 --log-every 1 --ckpt-dir "$QDIR/ckpt"
python -m repro.quant.calibrate --arch llama_60m --smoke \
  --ckpt-dir "$QDIR/ckpt" --out "$QDIR/quant"
python -m repro.launch.serve --arch llama_60m --smoke --paged --block-len 8 \
  --quant-ckpt "$QDIR/quant" --requests 4 --slots 2 --new-tokens 4 \
  --max-len 64 --metrics-out "$OBS_DIR/serve.jsonl"

echo "== obs smoke: metrics JSONL parses, traces validate =="
python - "$OBS_DIR" <<'EOF'
import json, sys
from repro.obs import trace as obs_trace
d = sys.argv[1]
for name in ("train", "serve"):
    lines = [json.loads(l) for l in open(f"{d}/{name}.jsonl")]
    assert lines and all("metrics" in l and "ts" in l for l in lines), name
    n = obs_trace.validate_file(f"{d}/{name}_trace.json")
    print(f"obs smoke: {name}: {len(lines)} JSONL line(s), "
          f"{n} valid trace events")
tm = lines  # serve lines from the loop's last iteration
h = tm[-1]["metrics"].get("serve.ttft_ticks")
assert h and h["count"] > 0 and "p50" in h, h
# wall-clock TTFT must be populated on every serve run (SLO currency):
# present, non-empty, and with a finite sum
hw = tm[-1]["metrics"].get("serve.ttft_wall_ms")
assert hw and hw["count"] > 0 and hw["sum"] >= 0, hw
EOF

echo "ci_check: all gates passed"
