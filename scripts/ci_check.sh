#!/usr/bin/env bash
# One-command regression gate: tier-1 tests + core smoke + a host-mesh
# dry-run through the repro.dist spec engine + the 2-device host-mesh
# smoke (compressed-DP, per_layer x grad_accum, distributed fused) + the
# llama_7b fsdp placement gate + paged serve smokes (gathered-view and
# paged-attention-kernel decode) + resilience smokes (chaos kill@3 ->
# relaunch -> bit-exact resume; serve slot-stall under a deadline with
# zero wedged requests). Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if ! python -c "import hypothesis" 2>/dev/null; then
  # try to heal the env first: when network allows, real hypothesis
  # replaces the propshim and the property tests get shrinking + fresh
  # examples. Offline (the common container case) this fails quietly and
  # the fallback notice below stands.
  pip install -q -r requirements-dev.txt 2>/dev/null || true
fi
# one unambiguous machine-greppable line naming the property-test engine
if python -c "import hypothesis" 2>/dev/null; then
  echo "property-engine: hypothesis $(python -c 'import hypothesis; print(hypothesis.__version__)') (full shrinking; pin: requirements-dev.txt)"
else
  echo "property-engine: propshim (tests/_propshim.py seeded-loop fallback — no shrinking, fixed examples; install requirements-dev.txt for hypothesis)"
fi

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: core SLTrain invariants =="
python scripts/smoke_core.py

echo "== dry-run: llama_60m x train_4k on the 256-chip host mesh =="
python -m repro.launch.dryrun --arch llama_60m --cell train_4k

echo "== host-mesh smoke: compressed-DP + wire model, per_layer+grad_accum=2, fused TP=2 =="
python scripts/hostmesh_smoke.py

echo "== fsdp gate: llama_7b placement residency + lower on the 8-device host mesh =="
python scripts/fsdp_dryrun.py

echo "== fused smoke: exec_mode=fused 3-step train on the Pallas path =="
python -m repro.launch.train --arch llama_60m --smoke --mode sltrain \
  --exec-mode fused --steps 3 --batch 2 --seq 16 --log-every 1 \
  --ckpt-dir "$(mktemp -d)"

echo "== per-layer smoke: update_mode=per_layer 8-bit 3-step train =="
OBS_DIR="$(mktemp -d)"
python -m repro.launch.train --arch llama_60m --smoke --mode sltrain \
  --update-mode per_layer --optimizer adam8bit --steps 3 --batch 2 --seq 16 \
  --log-every 1 --ckpt-dir "$(mktemp -d)" --layer-timing \
  --metrics-out "$OBS_DIR/train.jsonl" --trace-out "$OBS_DIR/train_trace.json"

echo "== serve smoke: paged KV engine, 3 staggered requests =="
python -m repro.launch.serve --arch llama_60m --smoke --paged --block-len 8 \
  --requests 3 --stagger --slots 2 --new-tokens 4 --max-len 64

echo "== serve smoke: paged-attention kernel decode (interpret mode) =="
python -m repro.launch.serve --arch llama_60m --smoke --paged \
  --attn-kernel paged --block-len 8 --requests 3 --stagger --slots 2 \
  --new-tokens 4 --max-len 64

echo "== serve smoke: continuous batching + copy-on-write prefix sharing =="
python -m repro.launch.serve --arch llama_60m --smoke --paged --block-len 8 \
  --stream --prefix-sharing --requests 4 --slots 2 --new-tokens 4 \
  --max-len 64 --metrics-out "$OBS_DIR/serve.jsonl" \
  --trace-out "$OBS_DIR/serve_trace.json"

echo "== quant smoke: train -> calibrate -> int8 serve =="
QDIR="$(mktemp -d)"
python -m repro.launch.train --arch llama_60m --smoke --mode sltrain \
  --steps 3 --batch 2 --seq 16 --log-every 1 --ckpt-dir "$QDIR/ckpt"
python -m repro.quant.calibrate --arch llama_60m --smoke \
  --ckpt-dir "$QDIR/ckpt" --out "$QDIR/quant"
python -m repro.launch.serve --arch llama_60m --smoke --paged --block-len 8 \
  --quant-ckpt "$QDIR/quant" --requests 4 --slots 2 --new-tokens 4 \
  --max-len 64 --metrics-out "$OBS_DIR/serve.jsonl"

echo "== resilience smoke: chaos kill@3 -> relaunch -> exact resume =="
RDIR="$(mktemp -d)"
python -m repro.launch.train --arch llama_60m --smoke --steps 6 --batch 2 \
  --seq 16 --log-every 1 --ckpt-every 2 --ckpt-dir "$RDIR/ref" \
  > "$RDIR/ref.log"
rc=0
python -m repro.launch.train --arch llama_60m --smoke --steps 6 --batch 2 \
  --seq 16 --log-every 1 --ckpt-every 2 --ckpt-dir "$RDIR/chaos" \
  --chaos kill@3 > "$RDIR/killed.log" 2>&1 || rc=$?
if [ "$rc" -ne 43 ]; then
  echo "chaos kill did not exit 43 (got $rc)"; exit 1
fi
python -m repro.launch.train --arch llama_60m --smoke --steps 6 --batch 2 \
  --seq 16 --log-every 1 --ckpt-every 2 --ckpt-dir "$RDIR/chaos" \
  > "$RDIR/resumed.log"
grep -q "resumed from step 2" "$RDIR/resumed.log"
diff <(grep '^final step' "$RDIR/ref.log") \
     <(grep '^final step' "$RDIR/resumed.log")
echo "resilience smoke: killed at step 3 (exit 43), resumed from step 2, final loss bit-exact"

echo "== resilience smoke: serve slot-stall + deadline, zero wedged =="
python -m repro.launch.serve --arch llama_60m --smoke --paged --block-len 8 \
  --stream --requests 4 --slots 2 --new-tokens 6 --max-len 64 \
  --chaos "stall@4:64" --deadline-ticks 24 \
  --metrics-out "$OBS_DIR/serve_chaos.jsonl"
python - "$OBS_DIR" <<'EOF'
import json, sys
m = json.loads(open(f"{sys.argv[1]}/serve_chaos.jsonl").read()
               .splitlines()[-1])["metrics"]
assert m["resilience.faults_injected{kind=stall}"]["value"] > 0, m
assert m["serve.deadline_exceeded"]["value"] > 0, \
    "stall@4:64 under a 24-tick deadline must cancel at least one request"
print("resilience smoke: stall injected, deadline cancellation counted, "
      "engine drained")
EOF

echo "== obs smoke: metrics JSONL parses, traces validate =="
python - "$OBS_DIR" <<'EOF'
import json, sys
from repro.obs import trace as obs_trace
d = sys.argv[1]
for name in ("train", "serve"):
    lines = [json.loads(l) for l in open(f"{d}/{name}.jsonl")]
    assert lines and all("metrics" in l and "ts" in l for l in lines), name
    n = obs_trace.validate_file(f"{d}/{name}_trace.json")
    print(f"obs smoke: {name}: {len(lines)} JSONL line(s), "
          f"{n} valid trace events")
tm = lines  # serve lines from the loop's last iteration
h = tm[-1]["metrics"].get("serve.ttft_ticks")
assert h and h["count"] > 0 and "p50" in h, h
# wall-clock TTFT must be populated on every serve run (SLO currency):
# present, non-empty, and with a finite sum
hw = tm[-1]["metrics"].get("serve.ttft_wall_ms")
assert hw and hw["count"] > 0 and hw["sum"] >= 0, hw
EOF

echo "ci_check: all gates passed"
