import jax, jax.numpy as jnp, numpy as np
import sys
sys.path.insert(0, "src")
from repro.core import sltrain, support

key = jax.random.PRNGKey(0)
d_in, d_out, r, delta = 64, 96, 8, 0.05
params, consts = sltrain.init_params(key, d_in, d_out, r, delta, dtype=jnp.float32, seed=3)
params = jax.tree.map(lambda t: jax.random.normal(jax.random.PRNGKey(7), t.shape, t.dtype) * 0.1, params)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 7, d_in), jnp.float32)
scale = 0.25

y = sltrain.sl_matmul(x, params, consts, scale)
W = sltrain.materialize(params, consts, scale)
y_ref = x @ W
print("fwd max err:", float(jnp.abs(y - y_ref).max()))

y_sp = sltrain.sl_matmul(x, params, consts, scale, exec_mode="sparse")
print("sparse-mode max err:", float(jnp.abs(y_sp - y_ref).max()))

# fused mode: same trainable params, extra tile consts from init
params_f, consts_f = sltrain.init_params(key, d_in, d_out, r, delta,
                                         dtype=jnp.float32, seed=3,
                                         exec_mode="fused")
params_f = jax.tree.map(lambda t: jax.random.normal(jax.random.PRNGKey(7), t.shape, t.dtype) * 0.1, params_f)
y_fu = sltrain.sl_matmul(x, params_f, consts_f, scale, exec_mode="fused")
print("fused-mode max err:", float(jnp.abs(y_fu - y_ref).max()))
assert float(jnp.abs(y_fu - y_ref).max()) < 1e-4


def loss_custom(p, x):
    return jnp.sum(jnp.sin(sltrain.sl_matmul(x, p, consts, scale)))


def loss_ref(p, x):
    W = sltrain.materialize(p, consts, scale)
    return jnp.sum(jnp.sin(x @ W))


g1, gx1 = jax.grad(loss_custom, argnums=(0, 1))(params, x)
g2, gx2 = jax.grad(loss_ref, argnums=(0, 1))(params, x)
for k in ("B", "A", "v"):
    print(f"grad {k} max err:", float(jnp.abs(g1[k] - g2[k]).max()))
print("grad x max err:", float(jnp.abs(gx1 - gx2).max()))

# support invariants
rows, cols = support.sample_support(0, 128, 256, 0.03, "row_balanced")
assert rows.shape == cols.shape
assert support.nnz_for(128, 256, 0.03, "row_balanced") == rows.shape[0]
rows_i, cols_i = support.sample_support(0, 128, 256, 0.03, "iid")
flat = rows_i.astype(np.int64) * 256 + cols_i
assert len(np.unique(flat)) == len(flat), "iid support has duplicates"
perm, local, counts, pad = support.tile_layout(rows, cols, 128, 256, 64, 64)
assert counts.sum() == rows.shape[0]
r2, c2, m2, cap = support.partition_support(rows, cols, 4, 256, "col")
assert m2.sum() == rows.shape[0]
assert (c2 < 64).all()
print("support ok; tile pad:", pad, "shard cap:", cap)
print("OK")
