"""llama_7b FSDP placement gate (ISSUE 8 acceptance).

Builds an 8-host-device (data=8, model=1) mesh, computes the FSDP spec
trees for the paper's llama_7b config (sltrain, r=1024, δ=0.05, bf16
params + f32 adamw moments), and asserts the MEASURED per-device
parameter + optimizer-state residency — summed over every leaf's
``NamedSharding.shard_shape`` — lands within 10% of the
``core/memory.training_estimate`` sharded prediction
((param_bytes + optim_bytes) / n_devices with ``moment_bytes=4`` and
the framework's int32 indices). Then AOT-lowers (and by default
compiles) the fsdp train step on the mesh via ``launch.dryrun.
lower_cell`` to prove the placement actually lowers end-to-end.

Usage:
  python scripts/fsdp_dryrun.py                # full gate (lower+compile)
  python scripts/fsdp_dryrun.py --skip-compile # residency check only
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8").strip()
# ^ must precede jax import: device count locks at first backend init.
import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import OptimizerConfig, ShapeCell
from repro.core import memory as memory_lib
from repro.dist import compat
from repro.dist import sharding as shl
from repro.models import registry
from repro.optim import optimizers

N_DEV = 8
ARCH = "llama_7b"
# small train cell: the gate is about PLACEMENT (params/opt residency),
# not activation scale — seq 256 × batch 8 keeps host-CPU compile cheap
CELL = ShapeCell("train_fsdp_smoke", 256, 8, "train")


def sharded_bytes(tree, specs, mesh):
    """Per-device bytes of ``tree`` placed per ``specs``: sum over leaves
    of prod(shard_shape) × itemsize."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(tree),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(
                                              x, jax.sharding.PartitionSpec))):
        shard = NamedSharding(mesh, spec).shard_shape(tuple(leaf.shape))
        total += int(np.prod(shard)) * jax.dtypes.canonicalize_dtype(
            leaf.dtype).itemsize
    return total


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-compile", action="store_true",
                    help="residency gate only; skip lower+compile")
    args = ap.parse_args(argv)

    assert jax.device_count() >= N_DEV, (
        f"need >= {N_DEV} host devices, got {jax.device_count()} — is "
        "another jax init clobbering xla_force_host_platform_device_count?")
    mesh = compat.make_mesh(
        (N_DEV, 1), ("data", "model"),
        axis_types=(compat.AxisType.Auto,) * 2)

    cfg = registry.get_config(ARCH)
    api = registry.get_api(cfg)
    params_abs, consts_abs = api.init(cfg, key=None)      # abstract init
    opt = optimizers.make(OptimizerConfig())              # adamw, f32 m/v
    opt_abs = jax.eval_shape(opt.init, params_abs)

    fsdp_axes = ("data",)
    p_specs = shl.param_specs(params_abs, mesh, fsdp_axes=fsdp_axes)
    c_specs = shl.param_specs(consts_abs, mesh, fsdp_axes=fsdp_axes)
    o_specs = shl.opt_state_specs(opt_abs, p_specs, mesh,
                                  fsdp_axes=fsdp_axes)

    measured = (sharded_bytes(params_abs, p_specs, mesh)
                + sharded_bytes(consts_abs, c_specs, mesh)
                + sharded_bytes(opt_abs, o_specs, mesh))

    pl = dict(memory_lib.PAPER_LLAMA["7b"])
    rank = pl.pop("rank")
    inv = memory_lib.llama_inventory(**pl)
    est = memory_lib.training_estimate(
        inv, "sltrain", optimizer="adamw", update_mode="global",
        rank=rank, delta=cfg.param.delta, dtype_bytes=2, index_bytes=4,
        support_kind=cfg.param.support_kind, moment_bytes=4)
    expected = (est.param_bytes + est.optim_bytes) / N_DEV

    rel = abs(measured - expected) / expected
    print(f"fsdp_dryrun[{ARCH} @ data={N_DEV}]: measured param+opt "
          f"{measured / 2**30:.3f} GiB/dev vs estimate "
          f"{expected / 2**30:.3f} GiB/dev (rel err {rel:.3%})")
    assert rel <= 0.10, (
        f"per-device residency off by {rel:.1%} (> 10%): measured "
        f"{measured} vs estimated {expected} bytes — FSDP specs are not "
        "sharding what core/memory says they should")

    # unsharded reference: the same state replicated would be N_DEV× larger
    ratio = (est.param_bytes + est.optim_bytes) / measured
    print(f"fsdp_dryrun: sharding factor {ratio:.2f}x "
          f"(ideal {N_DEV}x; gap = replicated small leaves)")

    if not args.skip_compile:
        from repro.launch import dryrun
        res = dryrun.lower_cell(ARCH, CELL, mesh=mesh, fsdp=True,
                                verbose=True)
        assert res["fsdp"], res
        bpd = res["bytes_per_device"]["argument"]
        print(f"fsdp_dryrun: compiled argument bytes "
              f"{bpd / 2**30:.3f} GiB/dev")
    print("fsdp_dryrun: gate passed")


if __name__ == "__main__":
    main()
