"""Diagnostic: lower one cell and print the largest collectives by wire
bytes (with while-loop trip multipliers) — the §Perf profiling tool.

Reuses ``launch/dryrun.py:lower_cell`` (which routes all sharding through
``repro.dist.sharding``) and only adds the per-collective HLO walk.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
import argparse
import collections
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--mode", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    from repro.analysis import hlo_parser
    from repro.configs.base import SHAPE_CELLS
    from repro.launch import dryrun

    cells = {c.name: c for c in SHAPE_CELLS}
    overrides = {}
    if args.sp:
        overrides["seq_shard_activations"] = True
    if args.mode:
        overrides["param_mode"] = args.mode

    _, compiled = dryrun.lower_cell(
        args.arch, cells[args.cell], multi_pod=args.multi_pod,
        remat=args.remat, cfg_overrides=overrides or None, verbose=False,
        with_compiled=True)

    txt = compiled.as_text()
    comps, entry = hlo_parser.parse_program(txt)
    pc = hlo_parser.analyze(txt)
    print(f"total wire: {pc.wire_bytes/1e12:.2f} TB  counts={pc.coll_counts}")
    print(f"trips: {pc.trip_counts}")

    # per-computation collective totals with trip multipliers
    trip_of_comp = {}
    for cname, comp in comps.items():
        for inst in comp.insts:
            if inst.op == "while":
                t = 1
                if inst.cond and inst.cond in comps:
                    t = comps[inst.cond].max_const
                for cn in inst.called:
                    trip_of_comp[cn] = max(trip_of_comp.get(cn, 1), t)

    agg = collections.Counter()
    for cname, comp in comps.items():
        t = trip_of_comp.get(cname, 1)
        for inst in comp.insts:
            if inst.op in hlo_parser._COLLECTIVES:
                opd = 0
                for nm in inst.operand_names:
                    rec = comp.symbols.get(nm)
                    if rec:
                        opd += rec[0]
                w = hlo_parser._wire(inst.op, opd or inst.operand_inline_bytes,
                                     inst.result_bytes, inst.attrs)
                key = (inst.op, inst.result_bytes, cname[:40])
                agg[key] += w * t
    for (op, rb, cname), w in agg.most_common(args.top):
        print(f"  {w/1e12:7.3f} TB  {op:18s} result={rb/2**20:8.1f}MiB "
              f"in {cname}")


if __name__ == "__main__":
    main()
