"""Diagnostic: lower one cell and print the largest collectives by wire
bytes (with while-loop trip multipliers) — the §Perf profiling tool."""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
import argparse
import collections
import re

from repro.analysis import hlo_parser


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--mode", default=None)
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    from repro.configs.base import SHAPE_CELLS
    from repro.launch import dryrun

    cells = {c.name: c for c in SHAPE_CELLS}
    overrides = {}
    if args.sp:
        overrides["seq_shard_activations"] = True
    if args.mode:
        overrides["param_mode"] = args.mode

    # reuse lower_cell but keep the compiled text
    import repro.launch.dryrun as dr
    import jax
    # monkeypatch-free: call the internals directly
    cell = cells[args.cell]
    res = None
    # replicate lower_cell but capture text
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh()
    # lower via the public helper, then re-lower to get text: simplest is to
    # copy the flow
    import repro.launch.dryrun as d
    # we just call lower_cell and recompute text by running analyze inside
    # -> instead: duplicate minimal flow
    from repro.models import registry
    from repro.launch import specs
    from repro.dist import sharding as shl
    from repro.optim import optimizers
    from repro.train import step as step_lib
    from repro.configs.base import OptimizerConfig
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = registry.get_config(args.arch, **{k: v for k, v in overrides.items()
                                            if k != "param_mode"})
    if "param_mode" in overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, param=dataclasses.replace(
            cfg.param, mode=overrides["param_mode"]))
    api = registry.get_api(cfg)
    params_abs, consts_abs = api.init(cfg, key=None)
    p_specs = shl.param_specs(params_abs, mesh)
    c_specs = shl.param_specs(consts_abs, mesh)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    if cell.kind in ("train", "prefill"):
        batch_abs = specs.input_specs(cfg, cell.global_batch, cell.seq_len,
                                      abstract=True)
        b_specs = shl.batch_specs(batch_abs, mesh, ("data",))
        opt = optimizers.make(OptimizerConfig())
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_specs = shl.opt_state_specs(opt_abs, p_specs, mesh)
        fn = step_lib.make_train_step(cfg, api, opt, remat=args.remat)
        jfn = jax.jit(fn, in_shardings=(ns(p_specs), ns(o_specs),
                                        ns(c_specs), ns(b_specs)),
                      out_shardings=(ns(p_specs), ns(o_specs), None))
        with mesh:
            compiled = jfn.lower(params_abs, opt_abs, consts_abs,
                                 batch_abs).compile()
    else:
        cache_abs = api.init_cache(cfg, cell.global_batch, cell.seq_len,
                                   abstract=True)
        k_specs = shl.cache_specs(cache_abs, mesh, batch_axes=("data",))
        tokens_abs, index_abs = specs.decode_inputs(cfg, cell.global_batch,
                                                    cell.seq_len,
                                                    abstract=True)
        b_spec = shl.batch_specs({"t": tokens_abs}, mesh, ("data",))["t"]
        fn = step_lib.make_serve_step(cfg, api)
        jfn = jax.jit(fn, in_shardings=(ns(p_specs), ns(c_specs),
                                        NamedSharding(mesh, b_spec),
                                        ns(k_specs), None),
                      out_shardings=(NamedSharding(mesh, b_spec), None,
                                     ns(k_specs)))
        with mesh:
            compiled = jfn.lower(params_abs, consts_abs, tokens_abs,
                                 cache_abs, index_abs).compile()

    txt = compiled.as_text()
    comps, entry = hlo_parser.parse_program(txt)
    pc = hlo_parser.analyze(txt)
    print(f"total wire: {pc.wire_bytes/1e12:.2f} TB  counts={pc.coll_counts}")
    print(f"trips: {pc.trip_counts}")

    # per-computation collective totals with trip multipliers
    trip_of_comp = {}
    for cname, comp in comps.items():
        for inst in comp.insts:
            if inst.op == "while":
                t = 1
                if inst.cond and inst.cond in comps:
                    t = comps[inst.cond].max_const
                for cn in inst.called:
                    trip_of_comp[cn] = max(trip_of_comp.get(cn, 1), t)

    def eff_trip(cname, depth=0):
        t = trip_of_comp.get(cname, 1)
        return t

    agg = collections.Counter()
    for cname, comp in comps.items():
        t = eff_trip(cname)
        for inst in comp.insts:
            if inst.op in hlo_parser._COLLECTIVES:
                opd = 0
                for nm in inst.operand_names:
                    rec = comp.symbols.get(nm)
                    if rec:
                        opd += rec[0]
                w = hlo_parser._wire(inst.op, opd or inst.operand_inline_bytes,
                                     inst.result_bytes, inst.attrs)
                key = (inst.op, inst.result_bytes, cname[:40])
                agg[key] += w * t
    for (op, rb, cname), w in agg.most_common(args.top):
        print(f"  {w/1e12:7.3f} TB  {op:18s} result={rb/2**20:8.1f}MiB "
              f"in {cname}")


if __name__ == "__main__":
    main()
