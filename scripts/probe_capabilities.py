import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
print("mesh ok:", mesh.shape)


def f(w, x):
    y = jnp.einsum("bd,dp->bp", x, w)
    return jnp.sum(y.astype(jnp.float32))


w = jax.ShapeDtypeStruct((4096, 8192), jnp.bfloat16)
x = jax.ShapeDtypeStruct((256, 4096), jnp.bfloat16)
ws = NamedSharding(mesh, P(None, "model"))
xs = NamedSharding(mesh, P(("pod", "data"), None))
with mesh:
    lowered = jax.jit(f, in_shardings=(ws, xs)).lower(w, x)
    c = lowered.compile()
    ca = c.cost_analysis()
    print("cost_analysis keys:", {k: v for k, v in ca.items() if "flops" in k or "bytes" in k})
    try:
        ma = c.memory_analysis()
        print("memory_analysis:", ma)
    except Exception as e:
        print("memory_analysis failed:", e)
    txt = c.as_text()
    coll = [l.strip()[:160] for l in txt.splitlines()
            if any(op in l for op in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"))]
    print("collectives in compiled HLO:", len(coll))
    for l in coll[:6]:
        print("  ", l)
