"""Slot scheduler for the paged serve engine: arrival-gated admission,
shared-prefix attach, batched (suffix-)prefill shaping, per-slot decode
positions, and block lifecycle.

The scheduler is pure host-side bookkeeping — it never touches device
arrays except to build the int32 inputs of the two jit'd programs:

* **Admission** (:meth:`admit`): queued requests are matched to free slots
  as long as their prompt fits the block pool AND they have arrived
  (``req.arrival`` vs the engine's tick clock — the continuous-batching
  stream loop admits into freed slots every decode step, so a request
  never waits for a drain). With ``prefix_sharing``, admission first asks
  the block table for the longest resident block-aligned prefix matching
  the prompt (:meth:`BlockTable.match_prefix`) and attaches those blocks
  read-only (refcount++); only the remaining suffix is prefilled. Admitted
  suffixes are padded to a shared power-of-two bucket length, so the
  batched prefill compiles once per bucket instead of once per prompt
  length. Rows of the prefill batch that belong to slots mid-decode get
  nulled block-table rows — their (garbage) writes land in the null
  block, never on live pages.
* **Decode shaping** (:meth:`decode_positions`): each active slot steps at
  its OWN position; idle slots sit at 0 with a nulled table row. This is
  the fix for the legacy engine's shared ``max(pos)`` write offset, where
  a lagging slot's K/V was scattered at another slot's position.
* **Block lifecycle**: blocks are allocated lazily as positions cross
  block boundaries (:meth:`ensure_decode_blocks`) and their refcounts
  dropped the moment a request finishes (:meth:`finish`) or its slot is
  preempted (:meth:`evict` — the engine requeues the request with its
  progress folded into ``resume`` and recomputes it later), so resident
  KV tracks live tokens. Shared prefix blocks return to the pool only
  when the LAST reader releases them.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.serve.kv import BlockTable, PagedLayout, blocks_for


def _bucket(n: int, minimum: int) -> int:
    """Smallest power-of-two ≥ n (and ≥ minimum) — bounds prefill
    recompiles at log2(max_len) program shapes."""
    b = max(minimum, 1)
    while b < n:
        b *= 2
    return b


def _ptoks(req) -> List[int]:
    """The tokens a (re-)admission must prefill: the original prompt, or
    prompt + generated-so-far for a preempted request (``resume``)."""
    return req.prompt if getattr(req, "resume", None) is None else req.resume


class Scheduler:
    """Owns slots, the request queue, and the block table."""

    def __init__(self, n_slots: int, max_len: int, layout: PagedLayout,
                 *, min_prefill_bucket: int = 8,
                 prefix_sharing: bool = False,
                 obs: Optional[obs_metrics.Registry] = None):
        self.n_slots = n_slots
        self.max_len = max_len
        self.blocks = BlockTable(layout, n_slots)
        self.pos = np.zeros(n_slots, np.int32)       # next write position
        self.slot_req: List[Optional[object]] = [None] * n_slots
        self.queue: List[object] = []
        self.min_prefill_bucket = min_prefill_bucket
        self.prefix_sharing = prefix_sharing
        # tokens the shared-prefix attach skipped prefilling for, per slot
        # (engine folds them into its prefill traffic model at admission)
        self._shared = np.zeros(n_slots, np.int32)
        # scheduler-level obs: the engine passes its registry so queue
        # pressure, admission batch shaping, and preemptions land in the
        # same snapshot as the engine counters
        self.obs = obs if obs is not None else obs_metrics.Registry()
        self._g_queue = self.obs.gauge(
            "serve.sched.queue_depth", help="queued requests after admit")
        self._h_admit = self.obs.histogram(
            "serve.sched.admitted_batch", buckets=range(1, n_slots + 1),
            help="requests admitted per batched prefill")
        self._c_preempt = self.obs.counter("serve.sched.preemptions")

    # -- admission ------------------------------------------------------------
    def submit(self, req) -> None:
        self.queue.append(req)

    @property
    def active_slots(self) -> List[int]:
        return [s for s in range(self.n_slots) if self.slot_req[s] is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def next_arrival(self) -> Optional[int]:
        """Earliest arrival tick among queued requests (None when the
        queue is empty or untimestamped) — the stream loop fast-forwards
        its clock here when every slot is idle."""
        ts = [getattr(r, "arrival", 0) or 0 for r in self.queue]
        return min(ts) if ts else None

    def admit(self, now: Optional[int] = None) -> List[Tuple[int, object]]:
        """Move queued, ARRIVED requests into free slots: attach any
        resident shared prefix read-only, then allocate the rest of the
        prompt's blocks. Stops at the first request the pool cannot hold
        or that has not arrived yet (FIFO, no reordering — queue order is
        arrival order) — it stays queued and retries next step. Prompt-
        length validation is the engine's job (submit time)."""
        admitted = []
        for s in range(self.n_slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue[0]
            if now is not None and (getattr(req, "arrival", 0) or 0) > now:
                break
            toks = _ptoks(req)
            plen = len(toks)
            shared = 0
            if self.prefix_sharing:
                # cap at plen - 1: at least one suffix token must run
                # through the model — its logits score the first output
                chain = self.blocks.match_prefix(toks, plen - 1)
                need_fresh = blocks_for(plen, self.blocks.layout.block_len) \
                    - len(chain)
                if need_fresh > self.blocks.free_blocks:
                    break
                shared = self.blocks.attach(s, chain)
            elif not self.blocks.can_fit(plen):
                break
            self.queue.pop(0)
            self.blocks.ensure(s, plen)
            self._shared[s] = shared
            self.slot_req[s] = req
            self.pos[s] = 0
            admitted.append((s, req))
        if admitted:
            self._h_admit.observe(len(admitted))
        self._g_queue.set(len(self.queue))
        return admitted

    def build_prefill(self, admitted) -> Tuple[np.ndarray, np.ndarray,
                                               np.ndarray, np.ndarray]:
        """(tokens (n_slots, bucket), lengths (n_slots,), offsets
        (n_slots,), table rows) for one batched SUFFIX prefill over the
        admitted slots: row s carries the prompt tokens from
        ``offsets[s]`` (the shared-prefix length, 0 without sharing) on,
        and the forward runs at true positions offset + i. Non-admitted
        rows carry zero tokens, length 1, offset 0, and a nulled table
        row. The bucket is capped at view_len; padding positions beyond
        offset + bucket are clamped INSIDE kv.scatter (never out of
        bounds, never into a shared block)."""
        bucket = min(_bucket(max(len(_ptoks(r)) - int(self._shared[s])
                                 for s, r in admitted),
                             self.min_prefill_bucket),
                     self.blocks.layout.view_len)
        tokens = np.zeros((self.n_slots, bucket), np.int32)
        lengths = np.ones(self.n_slots, np.int32)
        offsets = np.zeros(self.n_slots, np.int32)
        for s, req in admitted:
            toks = _ptoks(req)[int(self._shared[s]):]
            tokens[s, :len(toks)] = toks
            lengths[s] = len(toks)
            offsets[s] = self._shared[s]
        table = self.blocks.rows([s for s, _ in admitted])
        return tokens, lengths, offsets, table

    def finish_prefill(self, admitted) -> None:
        """Advance admitted slots past their prompts and publish each
        prompt's whole-block prefixes for future sharers."""
        for s, req in admitted:
            toks = _ptoks(req)
            self.pos[s] = len(toks)
            if self.prefix_sharing:
                self.blocks.register_prefix(s, toks, len(toks) - 1)

    # -- decode ---------------------------------------------------------------
    def ensure_decode_blocks(self, slots) -> List[int]:
        """Grow each slot's pages to hold one more position; returns the
        slots that actually have room (pool exhaustion parks the rest —
        they retry next step after other requests release blocks)."""
        ready = []
        for s in slots:
            if self.blocks.ensure(s, int(self.pos[s]) + 1):
                ready.append(s)
        return ready

    def decode_positions(self) -> np.ndarray:
        """(n_slots,) per-slot write positions; idle slots report 0 (their
        table row is all null block — writes are discarded)."""
        return self.pos.copy()

    def table(self) -> np.ndarray:
        return self.blocks.table

    def advance(self, slot: int) -> None:
        self.pos[slot] += 1

    def finish(self, slot: int) -> None:
        """Release the slot and drop its reference on every block it
        held (shared blocks stay resident for their other readers)."""
        self.blocks.release(slot)
        self.slot_req[slot] = None
        self.pos[slot] = 0
        self._shared[slot] = 0

    def evict(self, slot: int):
        """Preempt ``slot``: free its blocks and hand its request back to
        the engine (which requeues it for recompute)."""
        self._c_preempt.inc()
        req = self.slot_req[slot]
        self.blocks.release(slot)
        self.slot_req[slot] = None
        self.pos[slot] = 0
        self._shared[slot] = 0
        return req

    def preempt_youngest(self):
        """Evict the most recently submitted active request, fold its
        progress into ``resume`` (minus the not-yet-consumed last output
        token — greedy decode regenerates it exactly on readmission) and
        put it back at the queue head. Returns the request so the caller
        can apply its no-progress policy. All queue/slot/block mutations
        stay inside the scheduler."""
        victim = max(self.active_slots, key=lambda s: self.slot_req[s].uid)
        req = self.evict(victim)
        req.resume = req.prompt + req.out[:-1]
        req.out = req.out[:-1]
        self.queue.insert(0, req)
        return req
