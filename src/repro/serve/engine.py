"""Batched serving engine with continuous batching over fixed slots.

The engine keeps a fixed decode batch of ``n_slots`` sequences; finished
or empty slots are refilled from the request queue (continuous batching —
the decode step never waits for the longest request). Each slot carries
its own position counter; attention masking uses per-slot lengths, so one
jit'd ``decode_fn`` serves heterogeneous requests.

SLTrain tie-in (DESIGN §3, beyond-paper): the engine can run the model
with ``param.exec_mode="sparse"`` so decode reads only the factored
parameter bytes — the paper's compression ratio becomes decode bandwidth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.train import step as step_lib


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, consts, *, n_slots: int = 4,
                 max_len: int = 256, sparse_decode: bool = False, mesh=None):
        if sparse_decode and cfg.param.mode == "sltrain":
            cfg = dataclasses.replace(
                cfg, param=dataclasses.replace(cfg.param, exec_mode="sparse"))
        self.cfg = cfg
        self.params, self.consts = params, consts
        self.api = registry.get_api(cfg)
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = self.api.init_cache(cfg, n_slots, max_len)
        self.mesh = mesh
        if mesh is not None:
            # place weights + KV cache per the dist.sharding spec engine
            # (TP output sharding, heads-sharded cache); decode steps then
            # trace under the mesh so ambient constraints apply.
            from repro.dist import sharding as dist_sharding
            self.params = dist_sharding.place(self.params, mesh)
            self.consts = dist_sharding.place(self.consts, mesh)
            self.cache = dist_sharding.place(
                self.cache, mesh, dist_sharding.cache_specs(self.cache, mesh))
        self.pos = np.zeros(n_slots, dtype=np.int32)       # next position
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self._uid = 0
        self._decode_fn = jax.jit(step_lib.make_serve_step(cfg, self.api))
        self._steps = 0

    def _decode(self, *args):
        if self.mesh is None:
            return self._decode_fn(*args)
        with self.mesh:
            return self._decode_fn(*args)

    # -- API --------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> Request:
        self._uid += 1
        req = Request(self._uid, list(prompt), max_new_tokens)
        self.queue.append(req)
        return req

    def _prefill(self, slot: int, req: Request) -> None:
        """Prefill by stepping the prompt through decode (slot-local). A
        production engine would batch-prefill; slot-wise keeps the jit'd
        program count at one for this reference engine."""
        self.pos[slot] = 0
        for t in req.prompt:
            tok = np.zeros((self.n_slots, 1), np.int32)
            tok[slot, 0] = t
            _, _, self.cache = self._decode(
                self.params, self.consts, jnp.asarray(tok), self.cache,
                jnp.int32(self.pos[slot]))
            self.pos[slot] += 1
        req.out = []

    def _refill(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill(s, req)
                self.slot_req[s] = req

    def step(self) -> int:
        """One batched decode step over all active slots. Returns the number
        of active slots stepped."""
        self._refill()
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return 0
        tok = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            hist = req.prompt + req.out
            tok[s, 0] = hist[-1]
        # NOTE single shared index: reference engine steps slots at their own
        # pos via per-slot prefill; decode uses the max pos (KV slots beyond a
        # short request hold zeros — masked by causal length in attention).
        idx = int(max(self.pos[s] for s in active))
        nxt, _, self.cache = self._decode(self.params, self.consts,
                                          jnp.asarray(tok), self.cache,
                                          jnp.int32(idx))
        nxt = np.asarray(nxt)
        self._steps += 1
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(nxt[s, 0]))
            self.pos[s] += 1
            if len(req.out) >= req.max_new_tokens or \
                    self.pos[s] >= self.max_len - 1:
                req.done = True
                self.slot_req[s] = None
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[str, Any]:
        done: List[Request] = []
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return {"decode_steps": self._steps}
