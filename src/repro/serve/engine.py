"""Serving engine: continuous batching over fixed slots, with a paged KV
cache, copy-on-write prefix sharing, batched (suffix-)prefill, and
per-slot decode positions.

Two run loops: :meth:`ServeEngine.run_until_drained` (drain-style — admit
whatever is queued, run to empty; the PR-2 entry point) and
:meth:`ServeEngine.run_stream` (continuous batching — requests carry
arrival tick stamps, admission happens inside the decode loop as slots
free up, TTFT is measured on the engine's dispatch clock). With
``prefix_sharing=True`` an admission whose prompt matches a resident
block-aligned prefix attaches those pages read-only (refcount++) and
prefills only the divergent suffix through the chunked paged-prefill
path — prefill FLOPs and K/V writes for an N-way shared prefix drop
N× → 1× (``prefill_traffic`` counts the split).

Two cache layouts share the engine API:

* ``paged=True`` (the production path) — K/V lives in block pools
  (serve/kv.py) addressed through a per-slot block table; a scheduler
  (serve/scheduler.py) assigns slots, allocates/frees blocks as sequences
  grow and finish, and shapes the two jit'd programs. **Prefill is
  batched**: every admitted prompt runs through one train-style
  chunked-attention forward that scatters K/V into the slot's pages and
  emits each request's first token — O(1) dispatches per admission batch
  instead of O(prompt_len) per request. **Decode is per-slot**: each
  active slot writes at its own position via a ``(n_slots,)`` index
  vector, so a lagging slot never scatters K/V at another slot's offset.
* ``paged=False`` (legacy reference) — one contiguous ``(n_slots,
  max_len)`` cache, slot-wise prefill through the decode step, and a
  single shared ``max(pos)`` write index. Kept as the baseline the paged
  path is benchmarked against (benchmarks/serve_bench.py) and for its
  original tests; its shared-index wart is exactly what the per-slot
  vector removes.

SLTrain tie-in (DESIGN §3, beyond-paper): either layout can run the model
with ``param.exec_mode="sparse"`` so decode reads only the factored
parameter bytes — the paper's compression ratio becomes decode bandwidth.
``exec_mode="quant"`` goes one step further: the engine serves a
post-training int8 artifact (repro.quant) whose sparse values are int8
tile-CSR codes dequantized inside the Pallas decode kernel — the sparse
term's per-nonzero payload drops 12 B → 5 B (engine construction
validates the calibrated consts are present; exec_mode kwarg below).
The paged layout makes KV *accounting* proportional to live tokens —
blocks alloc/free as requests grow and finish, so the pool can be
oversubscribed (``n_blocks`` below worst case) and backpressure/preempt
instead of reserving ``n_slots × max_len`` per request. The DEFAULT pool
is still allocated at full capacity up front. How decode READS the pools
is ``attn_kernel``: ``"gather"`` materializes the gathered ``(n_slots,
view_len)`` per-slot view per layer as a transient — peak decode memory
matches the contiguous cache; ``"paged"`` (the config default on a paged
engine — a non-paged engine silently downgrades to "gather") routes
through the Pallas paged-attention kernels (kernels/paged_attention.py)
which stream K/V blocks through VMEM, so per-layer decode HBM traffic
tracks live tokens instead of ``n_slots × view_len`` (the ``kv_traffic``
counters model both; benchmarks/serve_bench.py reports them).

Observability (repro.obs): every counter above is a registry instrument —
the ``dispatches``/``prefill_traffic``/``kv_traffic`` attributes are
read-only :class:`repro.obs.metrics.MetricView` shims over them, so old
readers keep working while ``obs.snapshot()``/JSONL export and the TTFT
histograms (``serve.ttft_ticks`` exact on the tick clock,
``serve.ttft_wall_ms`` on the monotonic clock) come for free. With a
``Trace`` attached the engine additionally emits wall spans per phase
(admission, prefill dispatch, decode dispatch, block-until-ready) and a
tick-timeline lifecycle per request (queued → prefill → decode, one lane
per uid at 1 tick = ``trace.TICK_US`` us) whose span geometry reproduces
each request's tick TTFT exactly.

Resilience (repro.resilience; tests/test_resilience_serve.py): every
submitted request reaches a TERMINAL ``Request.status`` — ``done``,
``rejected``, ``timed_out``, or ``failed`` — the engine never silently
loses one.

* **Load shedding** — ``max_queue`` caps the admission queue; a submit
  past the cap returns the request immediately with
  ``status="rejected"`` and a structured ``fail_reason`` (counted on
  ``serve.rejected``) instead of growing the queue without bound.
* **Deadlines** — ``deadline_ticks`` (per request or engine default;
  launcher ``--deadline-ticks``) and/or a wall deadline (``deadline_ms``)
  cancel a request that has not completed within its budget of arrival:
  its slot's pages/refcounts are released through ``sched.finish`` and it
  lands in ``status="timed_out"`` (counted on ``serve.deadline_exceeded``).
* **Fault injection** — ``tick_hook`` runs at the top of every engine
  step (``ChaosEngine.serve_hook`` wires the ``stall@T:K`` fault);
  :meth:`stall_slot` freezes a slot for K ticks — the loop decodes around
  it, and when EVERY active slot is stalled the clock still advances so
  stalls and deadlines expire instead of spinning.
* **Budget exhaustion** — a run loop that exhausts ``max_steps`` marks
  the survivors ``status="failed"`` with a structured reason and returns
  a nonzero-aware ``summary``; the requests stay queued/resident, so
  calling the run loop again resumes and finishes them.
* **Quant fallback** — ``quant_fallback=True`` lets an
  ``exec_mode="quant"`` engine whose consts fail artifact validation
  degrade to the validated bf16 ``sparse`` path (warn + counted on
  ``serve.quant_fallback``) instead of refusing to serve; the default
  remains fail-at-construction.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.kv import PagedLayout
from repro.serve.scheduler import Scheduler
from repro.train import step as step_lib


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    # preemption state (paged engine): prompt + generated tokens to
    # recompute on readmission, and a no-progress counter that bounds
    # evict/readmit cycles on a hopelessly undersized pool
    resume: Optional[List[int]] = None
    stalls: int = 0
    _progress_mark: int = -1
    # stream timing, in engine clock ticks (= jit dispatches, the
    # deterministic unit of serving work): when the request arrives, when
    # it is admitted to a slot, when its first token lands, when it
    # completes. TTFT = t_first - arrival.
    arrival: int = 0
    t_admit: Optional[int] = None
    t_first: Optional[int] = None
    t_done: Optional[int] = None
    # the same milestones on the MONOTONIC wall clock (time.perf_counter
    # seconds) — ticks are the deterministic test currency, wall time is
    # what an SLO means. ``wall_arrival`` stamps submit() time: for a
    # request submitted ahead of its tick ``arrival``, wall TTFT measures
    # from submission while tick TTFT measures from the stamped arrival.
    wall_arrival: Optional[float] = None
    wall_admit: Optional[float] = None
    wall_first: Optional[float] = None
    wall_done: Optional[float] = None
    # resilience: lifecycle status (queued → active → one of the terminal
    # states done/rejected/timed_out/failed), the structured reason for a
    # non-done terminal state, and the completion deadline as a tick
    # budget from ``arrival`` (None = no deadline; the engine-level wall
    # deadline applies independently)
    status: str = "queued"
    fail_reason: Optional[str] = None
    deadline_ticks: Optional[int] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, consts, *, n_slots: int = 4,
                 max_len: int = 256, sparse_decode: bool = False,
                 exec_mode: Optional[str] = None, mesh=None,
                 paged: bool = False, block_len: int = 16, n_blocks: int = 0,
                 attn_kernel: Optional[str] = None,
                 prefix_sharing: bool = False,
                 obs: Optional[obs_metrics.Registry] = None,
                 trace: Optional[obs_trace.Trace] = None,
                 max_queue: Optional[int] = None,
                 deadline_ticks: Optional[int] = None,
                 deadline_ms: Optional[float] = None,
                 tick_hook=None, quant_fallback: bool = False):
        if exec_mode is not None:
            # explicit serve-time execution mode (supersedes the bool
            # sparse_decode shorthand; "quant" is the int8 artifact path)
            if sparse_decode:
                raise ValueError("pass either sparse_decode or exec_mode, "
                                 "not both")
            if cfg.param.mode != "sltrain":
                raise ValueError(f"exec_mode={exec_mode!r} requires "
                                 "param.mode='sltrain'")
            if exec_mode not in ("dense", "sparse", "fused", "quant"):
                raise ValueError(f"unknown exec_mode {exec_mode!r}")
            cfg = dataclasses.replace(
                cfg, param=dataclasses.replace(cfg.param,
                                               exec_mode=exec_mode))
        if sparse_decode and cfg.param.mode == "sltrain":
            cfg = dataclasses.replace(
                cfg, param=dataclasses.replace(cfg.param, exec_mode="sparse"))
        quant_fell_back = False
        if cfg.param.mode == "sltrain" and cfg.param.exec_mode == "quant":
            # fail at construction, not first dispatch: quant decode needs
            # the calibrated int8 consts from a quant artifact
            def _leaf_names(tree):
                return {p[-1].key if hasattr(p[-1], "key") else str(p[-1])
                        for p, _ in
                        jax.tree_util.tree_flatten_with_path(tree)[0]}
            if "qv_t" not in _leaf_names(consts):
                # quant_fallback: degrade to the bf16 sparse path instead,
                # but only after validating the factored sparse leaves the
                # fallback needs actually exist — a blind downgrade would
                # just move the failure to the first dispatch
                if quant_fallback and "cols" in _leaf_names(consts) and \
                        "v" in _leaf_names(params):
                    import warnings
                    warnings.warn(
                        "quant artifact validation failed (consts lack "
                        "qv_t): serving degraded to exec_mode='sparse' "
                        "(bf16 factored decode)")
                    cfg = dataclasses.replace(
                        cfg, param=dataclasses.replace(cfg.param,
                                                       exec_mode="sparse"))
                    quant_fell_back = True
                else:
                    raise ValueError(
                        "exec_mode='quant' needs calibrated consts (qv_t/"
                        "rows_q/cols_q/qscale) — load a repro.quant "
                        "artifact (python -m repro.quant.calibrate) and "
                        "pass its params/consts")
        if attn_kernel is not None:
            cfg = dataclasses.replace(cfg, attn_kernel=attn_kernel)
        if cfg.attn_kernel not in ("gather", "paged"):
            raise ValueError(f"attn_kernel {cfg.attn_kernel!r}: expected "
                             "'gather' or 'paged'")
        if cfg.attn_kernel == "paged" and not paged:
            if attn_kernel is not None:
                raise ValueError("attn_kernel='paged' requires the paged KV "
                                 "cache (paged=True): the kernel reads block "
                                 "pools, not the contiguous layout")
            # the config DEFAULT is "paged"; a contiguous-cache engine has
            # no block pools to stream, so fall back to the gather read
            # path rather than rejecting every default-config legacy engine
            cfg = dataclasses.replace(cfg, attn_kernel="gather")
        if prefix_sharing and not paged:
            raise ValueError("prefix_sharing requires the paged KV cache "
                             "(paged=True): sharing attaches block-table "
                             "entries, which the contiguous layout lacks")
        self.cfg = cfg
        self.params, self.consts = params, consts
        self.api = registry.get_api(cfg)
        self.n_slots = n_slots
        self.max_len = max_len
        self.paged = paged
        # each engine defaults to its OWN registry so side-by-side engines
        # (benchmarks compare four per run) never share counters; pass a
        # registry to aggregate. The trace default is a disabled recorder
        # (span() is a no-op) — hot loops pay one attribute check.
        self.obs = obs if obs is not None else obs_metrics.Registry()
        self.trace = trace if trace is not None else \
            obs_trace.Trace(enabled=False)
        if paged:
            if self.api.prefill_step is None:
                raise ValueError(f"family {cfg.family!r} has no prefill_step;"
                                 " the paged engine requires one")
            layout = PagedLayout.plan(n_slots, max_len, block_len, n_blocks)
            self.layout = layout
            self.cache = self.api.init_cache(cfg, n_slots, max_len,
                                             paged=True, block_len=block_len,
                                             n_blocks=layout.n_blocks)
            self.sched = Scheduler(n_slots, max_len, layout,
                                   prefix_sharing=prefix_sharing,
                                   obs=self.obs)
            self._prefill_fn = jax.jit(step_lib.make_prefill_step(cfg, self.api))
        else:
            self.cache = self.api.init_cache(cfg, n_slots, max_len)
            self.sched = None
        self.mesh = mesh
        if mesh is not None:
            # place weights + KV cache per the dist.sharding spec engine
            # (TP output sharding, heads-sharded cache); steps then trace
            # under the mesh so ambient constraints apply.
            from repro.dist import sharding as dist_sharding
            self.params = dist_sharding.place(self.params, mesh)
            self.consts = dist_sharding.place(self.consts, mesh)
            self.cache = dist_sharding.place(
                self.cache, mesh,
                dist_sharding.cache_specs(self.cache, mesh, paged=paged,
                                          attn_kernel=cfg.attn_kernel))
        self.prefix_sharing = prefix_sharing
        self.pos = np.zeros(n_slots, dtype=np.int32)       # next position
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self._parked = False          # any active slot waiting for blocks
        self._uid = 0
        self._decode_fn = jax.jit(step_lib.make_serve_step(cfg, self.api))
        self._steps = 0
        # engine clock, in jit dispatches (prefill or decode, each += 1):
        # the deterministic time base for arrivals and TTFT. Per-token
        # legacy prefill burns len(prompt) ticks where the batched paged
        # prefill burns 1 — exactly the dispatch economics being measured.
        self.clock = 0
        # registry instruments behind the legacy counter-dict attributes.
        # jit dispatch counters (benchmarks/serve_bench.py reads these to
        # show batched prefill is O(1) dispatches per admission batch);
        disp = self.obs.counter("serve.dispatches",
                                help="jit dispatches by phase")
        self._c_disp = {k: disp.labels(phase=k)
                        for k in ("prefill", "decode")}
        # prefill token traffic (paged engine): "shared" counts prompt
        # tokens whose K/V came from attaching resident prefix blocks —
        # never recomputed, never rewritten. serve_bench turns the split
        # into modeled prefill HBM bytes saved by copy-on-write sharing;
        ptok = self.obs.counter("serve.prefill.tokens",
                                help="prompt tokens by provenance")
        self._c_prefill = {f"tokens_{k}": ptok.labels(kind=k)
                           for k in ("total", "prefilled", "shared")}
        # per-decode-step KV-traffic model (paged engine): the gather path
        # reads n_slots × view_len K/V rows per layer, the paged kernel
        # reads each active slot's blocks. "live" counts attended
        # positions (pos + 1), "resident" block-rounds them — serve_bench
        # turns these into modeled HBM bytes for the two attn_kernel paths.
        self._c_kv = {k: self.obs.counter(f"serve.kv.{k}")
                      for k in ("steps", "gather_tokens", "live_tokens",
                                "resident_tokens", "active_slots")}
        self._c_done = self.obs.counter("serve.requests.completed")
        self._c_sub = self.obs.counter("serve.requests.submitted")
        # latency histograms, one per clock (the obs contract: assert on
        # ticks, report both). Unit tick buckets make the SLO harness's
        # bucket percentiles EXACT for tick-valued TTFTs.
        self._h_ttft = self.obs.histogram(
            "serve.ttft_ticks", buckets=obs_metrics.tick_buckets(),
            help="time to first token, engine clock ticks")
        self._h_ttft_ms = self.obs.histogram(
            "serve.ttft_wall_ms", buckets=obs_metrics.ms_buckets(),
            help="time to first token, wall ms from submit")
        self._h_e2e = self.obs.histogram(
            "serve.e2e_ticks", buckets=obs_metrics.tick_buckets(),
            help="arrival to completion, engine clock ticks")
        # read-only dict-shaped views, name-for-name with the dicts they
        # replaced (PR 2/5/6 API) — reads stay valid, writes now raise
        self._dispatches_view = obs_metrics.MetricView(self._c_disp)
        self._prefill_view = obs_metrics.MetricView(self._c_prefill)
        self._kv_view = obs_metrics.MetricView(self._c_kv)
        # -- resilience (module docstring: Resilience section) ------------
        self.max_queue = max_queue
        self.default_deadline_ticks = deadline_ticks
        self._deadline_s = None if deadline_ms is None else deadline_ms / 1e3
        self.tick_hook = tick_hook
        self._stall_until: Dict[int, int] = {}
        self.rejected: List[Request] = []
        self.timed_out: List[Request] = []
        self._c_rejected = self.obs.counter(
            "serve.rejected",
            help="requests shed at submit (admission queue at max_queue)")
        self._c_deadline = self.obs.counter(
            "serve.deadline_exceeded",
            help="requests cancelled past their tick/wall deadline")
        self._c_qfall = self.obs.counter(
            "serve.quant_fallback",
            help="quant engines degraded to bf16-sparse at construction")
        if quant_fell_back:
            self._c_qfall.inc()
        self.quant_fell_back = quant_fell_back

    # -- legacy counter-dict views + measurement reset ------------------------
    @property
    def dispatches(self) -> obs_metrics.MetricView:
        """Read-only view over ``serve.dispatches{phase=...}``."""
        return self._dispatches_view

    @property
    def prefill_traffic(self) -> obs_metrics.MetricView:
        """Read-only view over ``serve.prefill.tokens{kind=...}``."""
        return self._prefill_view

    @property
    def kv_traffic(self) -> obs_metrics.MetricView:
        """Read-only view over the ``serve.kv.*`` counters."""
        return self._kv_view

    def reset_metrics(self) -> None:
        """Zero every obs instrument plus the derived measurement state
        (step counter, tick clock, completed list) — what a bench does
        after jit warmup. Live requests are untouched; call while idle."""
        self.obs.reset()
        self._steps = 0
        self.clock = 0
        self.completed.clear()

    def _run(self, fn, *args):
        if self.mesh is None:
            return fn(*args)
        with self.mesh:
            return fn(*args)

    # -- API --------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               arrival: Optional[int] = None,
               deadline_ticks: Optional[int] = None) -> Request:
        """Queue a request. Invalid prompts are rejected HERE so a bad
        request can never wedge the engine from inside step().

        ``arrival`` (clock ticks) timestamps when the request becomes
        visible to the stream loop — :meth:`run_stream` will not admit it
        before then (and fast-forwards an idle engine's clock to it). The
        default 0 means "already arrived", which is what the drain-style
        entry points assume. ``deadline_ticks`` overrides the engine-level
        completion deadline for this request (budget from ``arrival``).

        With ``max_queue`` set, a submit past the cap is SHED rather than
        queued: the returned request carries ``status="rejected"`` and a
        structured ``fail_reason`` — callers must check the status."""
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_len:
            raise ValueError(f"prompt of {len(prompt)} tokens ≥ max_len "
                             f"{self.max_len}")
        if self.paged:
            from repro.serve.kv import blocks_for
            need = blocks_for(len(prompt) + 1, self.layout.block_len)
            usable = self.layout.n_blocks - 1
            if need > usable:
                # admit() is FIFO with break-on-first-misfit: a request the
                # WHOLE pool cannot hold would starve everything behind it
                raise ValueError(
                    f"prompt needs {need} blocks but the pool only has "
                    f"{usable}: raise n_blocks or shorten the prompt")
        self._uid += 1
        req = Request(self._uid, list(prompt), max_new_tokens,
                      arrival=int(arrival or 0),
                      wall_arrival=time.perf_counter(),
                      deadline_ticks=(deadline_ticks
                                      if deadline_ticks is not None
                                      else self.default_deadline_ticks))
        self._c_sub.inc()
        queue = self.sched.queue if self.paged else self.queue
        if self.max_queue is not None and len(queue) >= self.max_queue:
            # load shedding: reject at admission instead of growing the
            # queue without bound — the terminal status IS the signal
            req.status = "rejected"
            req.fail_reason = (f"admission queue full ({len(queue)} queued "
                               f">= max_queue={self.max_queue})")
            self.rejected.append(req)
            self._c_rejected.inc()
            return req
        if self.paged:
            self.sched.submit(req)
        else:
            self.queue.append(req)
        return req

    def _complete(self, req: Request) -> None:
        req.done = True
        req.status = "done"
        req.fail_reason = None
        req.t_done = self.clock
        req.wall_done = time.perf_counter()
        self.completed.append(req)
        self._c_done.inc()
        if req.t_first is not None:
            self._h_ttft.observe(req.t_first - req.arrival)
            if req.wall_first is not None and req.wall_arrival is not None:
                self._h_ttft_ms.observe(
                    (req.wall_first - req.wall_arrival) * 1e3)
        self._h_e2e.observe(req.t_done - req.arrival)
        if self.trace.enabled:
            self._trace_request(req)

    def _trace_request(self, req: Request) -> None:
        """Emit the request's lifecycle on the TICK timeline (one lane per
        uid, 1 tick = TICK_US us): queued [arrival, t_admit) → prefill
        [t_admit, t_first) → decode [t_first, t_done). Span geometry
        reproduces the tick TTFT exactly ((prefill.ts + prefill.dur) -
        queued.ts == TTFT·TICK_US); the args carry both clocks."""
        k = obs_trace.TICK_US
        ta = req.t_admit if req.t_admit is not None else req.arrival
        tf = req.t_first if req.t_first is not None else ta
        ttft_ms = None
        if req.wall_first is not None and req.wall_arrival is not None:
            ttft_ms = round((req.wall_first - req.wall_arrival) * 1e3, 3)
        args = {"uid": req.uid, "arrival_tick": req.arrival,
                "t_first_tick": tf, "t_done_tick": req.t_done,
                "ttft_ticks": tf - req.arrival, "ttft_wall_ms": ttft_ms}
        self.trace.thread_name(req.uid, f"request {req.uid}")
        self.trace.event("queued", ts_us=req.arrival * k,
                         dur_us=(ta - req.arrival) * k, tid=req.uid,
                         cat="request", args=args)
        self.trace.event("prefill", ts_us=ta * k, dur_us=(tf - ta) * k,
                         tid=req.uid, cat="request", args=args)
        self.trace.event("decode", ts_us=tf * k,
                         dur_us=(req.t_done - tf) * k, tid=req.uid,
                         cat="request", args=args)

    # -- resilience: stalls, deadlines, budget exhaustion ---------------------
    def stall_slot(self, slot: int, ticks: int) -> None:
        """Freeze ``slot`` until the engine clock passes ``clock + ticks``
        (fault injection: ``ChaosEngine``'s ``stall@T:K``). The decode
        loop steps AROUND a stalled slot — its position does not advance
        and no token is consumed — and resumes it once the horizon passes.
        Repeated stalls extend, never shorten, the horizon."""
        self._stall_until[slot] = max(self._stall_until.get(slot, 0),
                                      self.clock + int(ticks))

    def _deadline_exceeded(self, req: Request, now: int) -> bool:
        if req.deadline_ticks is not None and \
                now - req.arrival >= req.deadline_ticks:
            return True
        if self._deadline_s is not None and req.wall_arrival is not None \
                and time.perf_counter() - req.wall_arrival > self._deadline_s:
            return True
        return False

    def _cancel(self, req: Request, reason: str) -> None:
        """Terminal-state a request that missed its deadline. The caller
        releases any slot/block state; this records the outcome."""
        req.status = "timed_out"
        req.fail_reason = reason
        req.t_done = self.clock
        req.wall_done = time.perf_counter()
        req.resume = None
        self.timed_out.append(req)
        self._c_deadline.inc()
        if self.trace.enabled:
            self.trace.event("timed_out",
                             ts_us=self.clock * obs_trace.TICK_US, dur_us=0,
                             tid=req.uid, cat="request",
                             args={"uid": req.uid, "reason": reason})

    def _expire_deadlines(self, now: int) -> None:
        """Cancel queued AND active requests past their tick/wall
        deadline. An active slot's pages and prefix refcounts go back to
        the pool through ``sched.finish`` — a timed-out request can never
        pin KV blocks — and any stall on the slot is cleared so the freed
        slot is immediately admissible."""
        queue = self.sched.queue if self.paged else self.queue
        for req in [r for r in queue if self._deadline_exceeded(r, now)]:
            queue.remove(req)
            self._cancel(req, f"deadline exceeded at tick {now} while "
                              "queued (never admitted)")
        if self.paged:
            for s in list(self.sched.active_slots):
                req = self.sched.slot_req[s]
                if self._deadline_exceeded(req, now):
                    self._cancel(req, f"deadline exceeded at tick {now} "
                                      f"with {len(req.out)} tokens decoded")
                    self.sched.finish(s)
                    self._stall_until.pop(s, None)
        else:
            for s in range(self.n_slots):
                req = self.slot_req[s]
                if req is not None and self._deadline_exceeded(req, now):
                    self._cancel(req, f"deadline exceeded at tick {now} "
                                      f"with {len(req.out)} tokens decoded")
                    self.slot_req[s] = None
                    self._stall_until.pop(s, None)

    def _revive_failed(self) -> None:
        """A prior bounded run marked the survivors ``failed``; they are
        still queued/resident, so a new run loop call RESUMES them — flip
        them back to live statuses first."""
        queue = self.sched.queue if self.paged else self.queue
        for req in self._unfinished():
            if req.status == "failed":
                req.status = "queued" if any(req is q for q in queue) \
                    else "active"
                req.fail_reason = None

    def _finish_run(self, max_steps: int, warn: bool) -> Dict[str, Any]:
        """Shared run-loop epilogue: every surviving request gets a
        TERMINAL ``failed`` status with a structured reason (it stays
        queued/resident — calling the run loop again resumes it), and the
        return dict carries a nonzero-aware ``summary`` plus the
        timed_out/rejected lists so no request outcome is silent."""
        unfinished = self._unfinished()
        for req in unfinished:
            req.status = "failed"
            req.fail_reason = (
                f"run loop budget exhausted (max_steps={max_steps}) before "
                "completion; the request is still resident — call the run "
                "loop again to resume it")
        if unfinished and warn:
            import warnings
            warnings.warn(f"run_until_drained: max_steps={max_steps} "
                          f"exhausted with {len(unfinished)} requests still "
                          "queued or mid-decode (see the 'unfinished' list)")
        summary = {"done": len(self.completed)}
        for key, n in (("failed", len(unfinished)),
                       ("timed_out", len(self.timed_out)),
                       ("rejected", len(self.rejected))):
            if n:
                summary[key] = n
        return {"decode_steps": self._steps,
                "completed": list(self.completed),
                "unfinished": unfinished,
                "exhausted": bool(unfinished),
                "timed_out": list(self.timed_out),
                "rejected": list(self.rejected),
                "summary": summary}

    # -- paged path ---------------------------------------------------------
    def _admit_paged(self, now: Optional[int] = None) -> None:
        """Admit queued requests and run ONE batched prefill over them.
        While any active slot is parked for blocks, admission pauses so
        freed blocks reach the parked slots first (otherwise an evicted
        request could readmit into them and starve the parked slot).
        ``now`` (the stream loop's clock) gates admission on arrival;
        None (drain-style entry points) admits anything queued."""
        if self._parked and self.sched.active_slots:
            return
        with self.trace.span("serve.admission", cat="engine"):
            admitted = self.sched.admit(now)
        if not admitted:
            return
        t_admit, wall_admit = self.clock, time.perf_counter()
        tokens, lengths, offsets, table = self.sched.build_prefill(admitted)
        pt = self._c_prefill
        for s, req in admitted:
            req.t_admit, req.wall_admit = t_admit, wall_admit
            req.status = "active"
            n = len(req.prompt if req.resume is None else req.resume)
            pt["tokens_total"].inc(n)
            pt["tokens_prefilled"].inc(n - int(offsets[s]))
            pt["tokens_shared"].inc(int(offsets[s]))
        self._c_disp["prefill"].inc()
        self.clock += 1
        args = (self.params, self.consts, jnp.asarray(tokens), self.cache,
                jnp.asarray(lengths), jnp.asarray(table))
        if self.prefix_sharing:
            # per-slot offsets switch prefill to the chunked-suffix path
            # (attends attached prefix pages in place); without sharing the
            # offsets are identically 0 and the legacy whole-prompt trace
            # is kept — no recompile, no behavior change
            args += (None, jnp.asarray(offsets))
        with self.trace.span("serve.prefill_dispatch", cat="engine",
                             slots=len(admitted)):
            first, _, self.cache = self._run(self._prefill_fn, *args)
        with self.trace.span("serve.block_until_ready", cat="engine"):
            first = np.asarray(first)
        wall_first = time.perf_counter()
        self.sched.finish_prefill(admitted)
        for s, req in admitted:
            tok = int(first[s, 0])
            if req.resume is None:
                req.out = [tok]
                req.t_first = self.clock
                req.wall_first = wall_first
            else:
                # recompute after preemption: the re-prefilled context is
                # prompt + out, so this sample regenerates the token the
                # eviction trimmed (greedy decode is deterministic)
                req.out.append(tok)
                req.resume = None
            if len(req.out) >= req.max_new_tokens:
                self._complete(req)
                self.sched.finish(s)
                self._stall_until.pop(s, None)

    def _evict_for_progress(self, active) -> None:
        """All active slots are parked: preempt the youngest request so the
        others can grow (scheduler.preempt_youngest does the state moves);
        the engine only decides WHEN preemption is futile and fails loud."""
        if len(active) == 1 and not self.sched.queue:
            raise RuntimeError(
                "paged KV pool too small for the active request: "
                f"{self.sched.blocks.free_blocks} free blocks and nothing "
                "left to evict — raise n_blocks or lower max_len")
        req = self.sched.preempt_youngest()
        total = len(req.prompt) + len(req.out)
        req.stalls = req.stalls + 1 if total <= req._progress_mark else 0
        req._progress_mark = total
        if req.stalls >= 3:
            raise RuntimeError(
                f"request {req.uid} evicted {req.stalls} times without "
                "progress: the pool cannot hold the working set — raise "
                "n_blocks or lower n_slots/max_len")

    def _step_paged(self, now: Optional[int] = None) -> int:
        if self.tick_hook is not None:
            self.tick_hook(self)
        self._expire_deadlines(self.clock if now is None else now)
        self._admit_paged(now)
        active = self.sched.active_slots
        if not active:
            return 0
        runnable = [s for s in active
                    if self._stall_until.get(s, 0) <= self.clock]
        if not runnable:
            # EVERY active slot is stalled: burn a tick anyway so stalls
            # and deadlines expire instead of the loop spinning forever
            self.clock += 1
            return 0
        # grow pages for this step's write; slots the pool cannot hold are
        # parked (they retry once other requests release blocks)
        ready = set(self.sched.ensure_decode_blocks(runnable))
        self._parked = bool(set(runnable) - ready)
        if not ready:
            self._evict_for_progress(runnable)
            return 0
        # stalled slots keep tok=0: their garbage K/V write lands at a
        # position their pos never advanced past, so the real token
        # overwrites it before it first becomes attendable
        tok = np.zeros((self.n_slots, 1), np.int32)
        for s in ready:
            tok[s, 0] = self.sched.slot_req[s].out[-1]
        pos_vec = self.sched.decode_positions()
        t = self._c_kv
        t["steps"].inc()
        t["gather_tokens"].inc(self.n_slots * self.layout.view_len)
        t["live_tokens"].inc(sum(int(self.sched.pos[s]) + 1 for s in ready))
        t["resident_tokens"].inc(sum(self.sched.blocks.alloc_tokens(s)
                                     for s in ready))
        t["active_slots"].inc(len(ready))
        self._c_disp["decode"].inc()
        self.clock += 1
        with self.trace.span("serve.decode_dispatch", cat="engine",
                             slots=len(ready)):
            nxt, _, self.cache = self._run(
                self._decode_fn, self.params, self.consts, jnp.asarray(tok),
                self.cache, jnp.asarray(pos_vec),
                jnp.asarray(self.sched.table()))
        with self.trace.span("serve.block_until_ready", cat="engine"):
            nxt = np.asarray(nxt)
        self._steps += 1
        for s in sorted(ready):
            req = self.sched.slot_req[s]
            req.out.append(int(nxt[s, 0]))
            self.sched.advance(s)
            if len(req.out) >= req.max_new_tokens or \
                    int(self.sched.pos[s]) >= self.max_len - 1:
                self._complete(req)
                self.sched.finish(s)
                self._stall_until.pop(s, None)
        return len(ready)

    # -- legacy contiguous path ----------------------------------------------
    def _prefill(self, slot: int, req: Request) -> None:
        """Prefill by stepping the prompt through decode (slot-local) —
        O(prompt_len) dispatches; the paged path replaces this with one
        batched prefill_step. The last prompt step's prediction seeds
        ``req.out`` (the request's first generated token), matching the
        paged prefill's semantics."""
        self.pos[slot] = 0
        req.t_admit, req.wall_admit = self.clock, time.perf_counter()
        req.status = "active"
        nxt = None
        for t in req.prompt:
            tok = np.zeros((self.n_slots, 1), np.int32)
            tok[slot, 0] = t
            self._c_prefill["tokens_total"].inc()
            self._c_prefill["tokens_prefilled"].inc()
            self._c_disp["prefill"].inc()
            self.clock += 1
            with self.trace.span("serve.prefill_dispatch", cat="engine"):
                nxt, _, self.cache = self._run(
                    self._decode_fn, self.params, self.consts,
                    jnp.asarray(tok), self.cache, jnp.int32(self.pos[slot]))
            self.pos[slot] += 1
        req.out = [int(np.asarray(nxt)[slot, 0])]
        req.t_first = self.clock
        req.wall_first = time.perf_counter()

    def _refill(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill(s, req)
                if len(req.out) >= req.max_new_tokens:
                    self._complete(req)
                else:
                    self.slot_req[s] = req

    def _step_legacy(self) -> int:
        if self.tick_hook is not None:
            self.tick_hook(self)
        self._expire_deadlines(self.clock)
        self._refill()
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return 0
        runnable = [s for s in active
                    if self._stall_until.get(s, 0) <= self.clock]
        if not runnable:
            self.clock += 1   # all stalled: burn a tick so stalls expire
            return 0
        tok = np.zeros((self.n_slots, 1), np.int32)
        for s in runnable:
            req = self.slot_req[s]
            tok[s, 0] = req.out[-1]
        # NOTE single shared index: the legacy engine steps slots at their
        # own pos via per-slot prefill; decode uses the max pos (a lagging
        # slot's K/V is written at that offset — the wart the paged path's
        # per-slot index vector removes).
        idx = int(max(self.pos[s] for s in runnable))
        self._c_disp["decode"].inc()
        self.clock += 1
        with self.trace.span("serve.decode_dispatch", cat="engine",
                             slots=len(runnable)):
            nxt, _, self.cache = self._run(
                self._decode_fn, self.params, self.consts, jnp.asarray(tok),
                self.cache, jnp.int32(idx))
        with self.trace.span("serve.block_until_ready", cat="engine"):
            nxt = np.asarray(nxt)
        self._steps += 1
        for s in runnable:
            req = self.slot_req[s]
            req.out.append(int(nxt[s, 0]))
            self.pos[s] += 1
            if len(req.out) >= req.max_new_tokens or \
                    self.pos[s] >= self.max_len - 1:
                self._complete(req)
                self.slot_req[s] = None
                self._stall_until.pop(s, None)
        return len(runnable)

    def step(self) -> int:
        """One engine step: admit + (batched prefill) + one batched decode
        over all active slots. Returns the number of slots stepped."""
        return self._step_paged() if self.paged else self._step_legacy()

    def _has_work(self) -> bool:
        if self.paged:
            return self.sched.has_work
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def _unfinished(self) -> List[Request]:
        """Requests still queued or mid-decode — what a bounded run loop
        left behind. Both run loops surface this in their return dict so
        callers can retry/report instead of losing requests to a log
        message."""
        if self.paged:
            active = [self.sched.slot_req[s] for s in self.sched.active_slots]
            return active + list(self.sched.queue)
        return [r for r in self.slot_req if r is not None] + list(self.queue)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[str, Any]:
        """Step until every request finished (or ``max_steps`` ran out).

        Drain-style entry point: arrival timestamps are IGNORED — whatever
        is queued is admissible immediately (the caller decided to drain
        it). Returns {"decode_steps": int, "completed": [Request, ...],
        "unfinished": [Request, ...], "exhausted": bool, "timed_out":
        [...], "rejected": [...], "summary": {...}} — ``exhausted`` is
        True when max_steps was used up with requests still queued or
        mid-decode; those requests land in ``unfinished`` with
        ``status="failed"`` and a structured reason, but stay resident:
        calling the run loop again resumes them."""
        self._revive_failed()
        for _ in range(max_steps):
            if not self._has_work():
                break
            self.step()
        return self._finish_run(max_steps, warn=True)

    def run_stream(self, max_steps: int = 100_000) -> Dict[str, Any]:
        """Continuous batching: admission happens INSIDE the decode loop.

        Every iteration admits queued requests whose ``arrival`` ≤ clock
        into freed slots (one batched suffix-prefill dispatch), then runs
        one batched decode step over all active slots — a request arriving
        mid-flight starts decoding next step, without waiting for the
        current set to drain. When every slot is idle the clock
        fast-forwards to the next arrival instead of spinning. Requires
        the paged engine (slot recycling + per-slot positions).

        Returns the same dict shape as :meth:`run_until_drained`;
        completed requests carry ``arrival``/``t_first``/``t_done`` tick
        stamps for TTFT accounting (benchmarks/serve_bench.py)."""
        if not self.paged:
            raise ValueError("run_stream requires the paged engine "
                             "(paged=True): continuous admission recycles "
                             "slots through the block-table scheduler")
        self._revive_failed()
        for _ in range(max_steps):
            if not self._has_work():
                break
            if not self.sched.active_slots:
                nxt = self.sched.next_arrival()
                if nxt is not None and nxt > self.clock:
                    self.clock = nxt      # idle engine: jump to next arrival
            self._step_paged(now=self.clock)
        return self._finish_run(max_steps, warn=False)
