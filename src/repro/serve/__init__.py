from repro.serve.engine import Request, ServeEngine  # noqa: F401
from repro.serve.kv import BlockTable, PagedLayout  # noqa: F401
from repro.serve.scheduler import Scheduler  # noqa: F401
