"""Block-paged KV cache: pool, block table, refcounted copy-on-write
prefix sharing, and jit gather/scatter.

The contiguous cache reserves ``n_slots × max_len`` KV rows per layer up
front; short requests waste most of it. The paged layout instead keeps a
**pool** of fixed-size blocks per layer, shaped
``(n_blocks, block_len, n_kv_heads, head_dim)``, shared by every slot. A
per-slot **block table** ``(n_slots, blocks_per_slot) int32`` maps a
slot's logical block index (position // block_len) to a physical block in
the pool; blocks are allocated as a sequence grows and returned to the
free list when the request finishes, so resident KV scales with live
tokens, not with ``n_slots × max_len``.

Physical block 0 is the **null block**: every unallocated table entry
points at it, so a scatter past a slot's allocated region (prompt padding
in a batched prefill, idle decode slots) lands in garbage that is never
attended to — positions beyond a slot's length are causally masked, and
real writes always precede the first read of their position. This keeps
the jit'd gather/scatter free of bounds logic.

**Prefix sharing (copy-on-write)**: K/V at position i is a pure function
of the token prefix tokens[:i+1] (and the params), so two requests whose
prompts agree on a block-aligned prefix can map those logical blocks to
the SAME physical blocks. Every physical block carries a refcount; a
block-chain hash map (``register_prefix``) records which resident block
holds each (prefix-of-full-blocks) so a later admission can
``match_prefix``/``attach`` them — refcount++ instead of re-prefilling.
Shared blocks are read-only by construction: sharing is always a strict
block-aligned PREFIX of the prompt, suffix prefill and decode only ever
write at positions ≥ the shared length, and growth appends fresh blocks.
``release`` decrements refcounts and returns a block to the free list
only at zero (never a double-free; the refcount property test pins the
accounting). Prefill FLOPs and K/V pool writes for an N-way shared
prefix drop N× → 1×.

Device-side helpers (:func:`gather_view`, :func:`scatter`) are pure jnp
gathers/scatters usable inside jit/scan; host-side allocation lives in
:class:`BlockTable`. The model never sees paging — attention receives the
gathered ``(n_slots, blocks_per_slot · block_len, H, hd)`` view (or, with
``attn_kernel="paged"``, streams the pools in place), which is exactly
the contiguous layout with ``max_len = blocks_per_slot·block_len``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def blocks_for(n_tokens: int, block_len: int) -> int:
    """Blocks needed to hold ``n_tokens`` positions."""
    return max(0, -(-n_tokens // block_len))


@dataclass(frozen=True)
class PagedLayout:
    """Static shape of one paged pool (per layer-stack leaf)."""
    n_blocks: int          # physical blocks in the pool (incl. null block 0)
    block_len: int         # tokens per block
    blocks_per_slot: int   # block-table width = ceil(max_len / block_len)

    @property
    def view_len(self) -> int:
        """Sequence length of the gathered per-slot view."""
        return self.blocks_per_slot * self.block_len

    @staticmethod
    def plan(n_slots: int, max_len: int, block_len: int,
             n_blocks: int = 0) -> "PagedLayout":
        """Default pool: full capacity (every slot at max_len) + null block.
        Pass ``n_blocks`` to oversubscribe (fewer blocks than worst case)."""
        per_slot = blocks_for(max_len, block_len)
        return PagedLayout(n_blocks or (1 + n_slots * per_slot), block_len,
                           per_slot)


# ---------------------------------------------------------------------------
# Device side: gather / scatter (pure, jit-safe)
# ---------------------------------------------------------------------------

def gather_view(pool, table):
    """Contiguous per-slot view of a paged pool.

    pool: (n_blocks, block_len, H, hd); table: (n_slots, blocks_per_slot)
    int32 → (n_slots, blocks_per_slot · block_len, H, hd). Unallocated
    entries read the null block — callers mask by per-slot length (the
    causal mask does this for free: garbage sits at positions the query
    has not reached)."""
    g = jnp.take(pool, table, axis=0)      # (S, bps, bl, H, hd)
    return g.reshape(g.shape[0], -1, *pool.shape[2:])


def scatter(pool, table, positions, new):
    """Write per-slot tokens into their pages.

    pool: (n_blocks, block_len, H, hd); table: (n_slots, blocks_per_slot);
    positions: (n_slots, S) int32 logical positions; new: (n_slots, S, H,
    hd). Returns the updated pool. Positions mapping to unallocated table
    entries land in the null block (duplicate writes there are benign).
    Positions are clamped to the table width explicitly — an offset
    prefill's padding rows (offset + bucket can exceed view_len) must
    never rest on out-of-bounds gather semantics. Clamped garbage lands
    at the slot's LAST logical block, which a block-aligned shared prefix
    can never own (sharing is capped below the prompt end) and which
    decode overwrites before the position is first attended."""
    bl = pool.shape[1]
    positions = jnp.minimum(positions, table.shape[1] * bl - 1)
    phys = jnp.take_along_axis(table, positions // bl, axis=1)  # (n_slots,S)
    flat_idx = (phys * bl + positions % bl).reshape(-1)
    flat = pool.reshape(-1, *pool.shape[2:])
    flat = flat.at[flat_idx].set(
        new.reshape(-1, *new.shape[2:]).astype(pool.dtype))
    return flat.reshape(pool.shape)


# ---------------------------------------------------------------------------
# Host side: block allocation + prefix sharing
# ---------------------------------------------------------------------------

class BlockTable:
    """Host-side block table + refcounted free-list allocator over a
    shared pool.

    One table serves every layer: layer pools are stacked leaves of the
    cache pytree, and a physical block id indexes the same slot's pages in
    each of them. Block 0 is reserved as the null block and never
    allocated. Fresh blocks start at refcount 1; :meth:`attach` bumps the
    count for each slot sharing a block; :meth:`release` decrements and
    frees at zero."""

    def __init__(self, layout: PagedLayout, n_slots: int):
        self.layout = layout
        self.n_slots = n_slots
        self.table = np.zeros((n_slots, layout.blocks_per_slot), np.int32)
        self._n_alloc = np.zeros(n_slots, np.int32)   # allocated per slot
        self._free: List[int] = list(range(layout.n_blocks - 1, 0, -1))
        self.refcount = np.zeros(layout.n_blocks, np.int32)
        # prefix map: tuple(tokens of a whole-block-aligned prefix) → the
        # physical block holding its LAST block. Chained lookups walk
        # longer and longer prefixes, so a hit set is always a prefix
        # chain of resident blocks.
        self._prefix_to_block: Dict[Tuple[int, ...], int] = {}
        self._block_prefix: Dict[int, Tuple[int, ...]] = {}

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.layout.n_blocks - 1 - len(self._free)

    def alloc_tokens(self, slot: int) -> int:
        """KV positions resident for ``slot`` (allocated blocks × block
        length) — the block-rounded footprint the traffic model reads."""
        return int(self._n_alloc[slot]) * self.layout.block_len

    def can_fit(self, n_tokens: int) -> bool:
        return blocks_for(n_tokens, self.layout.block_len) <= len(self._free)

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot`` to hold ``n_tokens`` positions; False if the pool
        is exhausted (caller backpressures the request queue). Newly
        allocated blocks are private (refcount 1); blocks the slot already
        holds — owned or attached — are never touched."""
        need = blocks_for(n_tokens, self.layout.block_len)
        if need > self.layout.blocks_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceed "
                f"{self.layout.view_len} (blocks_per_slot × block_len)")
        have = int(self._n_alloc[slot])
        if need <= have:
            return True
        if need - have > len(self._free):
            return False
        for j in range(have, need):
            b = self._free.pop()
            self.table[slot, j] = b
            self.refcount[b] = 1
        self._n_alloc[slot] = need
        return True

    def release(self, slot: int) -> None:
        """Drop ``slot``'s reference on every block it holds. A block
        returns to the free list (and leaves the prefix map) only when its
        LAST reference goes — shared prefix blocks survive as long as any
        slot still reads them."""
        n = int(self._n_alloc[slot])
        for j in range(n):
            b = int(self.table[slot, j])
            self.table[slot, j] = 0
            self.refcount[b] -= 1
            assert self.refcount[b] >= 0, f"block {b}: refcount underflow"
            if self.refcount[b] == 0:
                key = self._block_prefix.pop(b, None)
                if key is not None:
                    self._prefix_to_block.pop(key, None)
                self._free.append(b)
        self._n_alloc[slot] = 0

    # -- prefix sharing -----------------------------------------------------
    def match_prefix(self, tokens: Sequence[int],
                     max_tokens: int | None = None) -> List[int]:
        """Longest chain of resident full blocks matching ``tokens``'
        prefix: the physical block ids for blocks 0, 1, ... as long as
        every whole-block prefix is registered. ``max_tokens`` caps the
        match (callers pass len(prompt) - 1 so at least one suffix token
        is always left to prefill — its logits score the first output)."""
        bl = self.layout.block_len
        limit = len(tokens) if max_tokens is None else min(len(tokens),
                                                          max_tokens)
        chain: List[int] = []
        for j in range(limit // bl):
            b = self._prefix_to_block.get(tuple(tokens[:(j + 1) * bl]))
            if b is None:
                break
            chain.append(b)
        return chain

    def attach(self, slot: int, phys_blocks: Sequence[int]) -> int:
        """Map ``slot``'s leading logical blocks onto resident physical
        blocks (a :meth:`match_prefix` chain), bumping each refcount. The
        slot must be empty. Returns the number of shared TOKENS."""
        assert int(self._n_alloc[slot]) == 0, \
            f"slot {slot}: attach on a non-empty slot"
        for j, b in enumerate(phys_blocks):
            assert self.refcount[b] > 0, f"block {b}: attach to a free block"
            self.table[slot, j] = b
            self.refcount[b] += 1
        self._n_alloc[slot] = len(phys_blocks)
        return len(phys_blocks) * self.layout.block_len

    def register_prefix(self, slot: int, tokens: Sequence[int],
                        max_tokens: int | None = None) -> int:
        """After ``slot``'s K/V for ``tokens`` is resident, publish its
        whole-block prefixes so later admissions can attach. Capped at
        ``max_tokens`` (same cap as match_prefix: never publish a block a
        sharer could not legally attach). Registration is idempotent and
        first-writer-wins: an existing entry for the same token prefix is
        kept (both blocks hold identical K/V; keeping one maximizes
        sharing). Returns the number of blocks newly registered."""
        bl = self.layout.block_len
        limit = len(tokens) if max_tokens is None else min(len(tokens),
                                                          max_tokens)
        fresh = 0
        for j in range(limit // bl):
            key = tuple(tokens[:(j + 1) * bl])
            if key in self._prefix_to_block:
                continue
            b = int(self.table[slot, j])
            if b == 0 or b in self._block_prefix:
                continue
            self._prefix_to_block[key] = b
            self._block_prefix[b] = key
            fresh += 1
        return fresh

    def rows(self, slots) -> np.ndarray:
        """Table restricted to ``slots``: other rows are nulled so a
        batched prefill cannot clobber live pages of mid-decode slots.
        (Shared blocks stay IN the returned rows — suffix prefill reads
        them through the gathered view / paged kernel but its writes all
        land at positions ≥ the shared length.)"""
        out = np.zeros_like(self.table)
        for s in slots:
            out[s] = self.table[s]
        return out

    def as_array(self) -> jnp.ndarray:
        return jnp.asarray(self.table)

    def check(self) -> None:
        """Accounting invariants (test hook): every non-free block's
        refcount equals the number of table rows referencing it; free
        blocks have refcount 0; no block is both free and referenced."""
        refs = np.zeros(self.layout.n_blocks, np.int64)
        for s in range(self.n_slots):
            for j in range(int(self._n_alloc[s])):
                refs[int(self.table[s, j])] += 1
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate block in free list"
        for b in range(1, self.layout.n_blocks):
            if b in free:
                assert self.refcount[b] == 0 and refs[b] == 0, \
                    (b, int(self.refcount[b]), int(refs[b]))
            else:
                assert self.refcount[b] == refs[b] > 0, \
                    (b, int(self.refcount[b]), int(refs[b]))
        assert self.blocks_in_use + self.free_blocks == \
            self.layout.n_blocks - 1
