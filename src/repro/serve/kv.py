"""Block-paged KV cache: pool, block table, and jit gather/scatter.

The contiguous cache reserves ``n_slots × max_len`` KV rows per layer up
front; short requests waste most of it. The paged layout instead keeps a
**pool** of fixed-size blocks per layer, shaped
``(n_blocks, block_len, n_kv_heads, head_dim)``, shared by every slot. A
per-slot **block table** ``(n_slots, blocks_per_slot) int32`` maps a
slot's logical block index (position // block_len) to a physical block in
the pool; blocks are allocated as a sequence grows and returned to the
free list when the request finishes, so resident KV scales with live
tokens, not with ``n_slots × max_len``.

Physical block 0 is the **null block**: every unallocated table entry
points at it, so a scatter past a slot's allocated region (prompt padding
in a batched prefill, idle decode slots) lands in garbage that is never
attended to — positions beyond a slot's length are causally masked, and
real writes always precede the first read of their position. This keeps
the jit'd gather/scatter free of bounds logic.

Device-side helpers (:func:`gather_view`, :func:`scatter`) are pure jnp
gathers/scatters usable inside jit/scan; host-side allocation lives in
:class:`BlockTable`. The model never sees paging — attention receives the
gathered ``(n_slots, blocks_per_slot · block_len, H, hd)`` view, which is
exactly the contiguous layout with ``max_len = blocks_per_slot·block_len``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import jax.numpy as jnp
import numpy as np


def blocks_for(n_tokens: int, block_len: int) -> int:
    """Blocks needed to hold ``n_tokens`` positions."""
    return max(0, -(-n_tokens // block_len))


@dataclass(frozen=True)
class PagedLayout:
    """Static shape of one paged pool (per layer-stack leaf)."""
    n_blocks: int          # physical blocks in the pool (incl. null block 0)
    block_len: int         # tokens per block
    blocks_per_slot: int   # block-table width = ceil(max_len / block_len)

    @property
    def view_len(self) -> int:
        """Sequence length of the gathered per-slot view."""
        return self.blocks_per_slot * self.block_len

    @staticmethod
    def plan(n_slots: int, max_len: int, block_len: int,
             n_blocks: int = 0) -> "PagedLayout":
        """Default pool: full capacity (every slot at max_len) + null block.
        Pass ``n_blocks`` to oversubscribe (fewer blocks than worst case)."""
        per_slot = blocks_for(max_len, block_len)
        return PagedLayout(n_blocks or (1 + n_slots * per_slot), block_len,
                           per_slot)


# ---------------------------------------------------------------------------
# Device side: gather / scatter (pure, jit-safe)
# ---------------------------------------------------------------------------

def gather_view(pool, table):
    """Contiguous per-slot view of a paged pool.

    pool: (n_blocks, block_len, H, hd); table: (n_slots, blocks_per_slot)
    int32 → (n_slots, blocks_per_slot · block_len, H, hd). Unallocated
    entries read the null block — callers mask by per-slot length (the
    causal mask does this for free: garbage sits at positions the query
    has not reached)."""
    g = jnp.take(pool, table, axis=0)      # (S, bps, bl, H, hd)
    return g.reshape(g.shape[0], -1, *pool.shape[2:])


def scatter(pool, table, positions, new):
    """Write per-slot tokens into their pages.

    pool: (n_blocks, block_len, H, hd); table: (n_slots, blocks_per_slot);
    positions: (n_slots, S) int32 logical positions; new: (n_slots, S, H,
    hd). Returns the updated pool. Positions mapping to unallocated table
    entries land in the null block (duplicate writes there are benign)."""
    bl = pool.shape[1]
    phys = jnp.take_along_axis(table, positions // bl, axis=1)  # (n_slots,S)
    flat_idx = (phys * bl + positions % bl).reshape(-1)
    flat = pool.reshape(-1, *pool.shape[2:])
    flat = flat.at[flat_idx].set(
        new.reshape(-1, *new.shape[2:]).astype(pool.dtype))
    return flat.reshape(pool.shape)


# ---------------------------------------------------------------------------
# Host side: block allocation
# ---------------------------------------------------------------------------

class BlockTable:
    """Host-side block table + free-list allocator over a shared pool.

    One table serves every layer: layer pools are stacked leaves of the
    cache pytree, and a physical block id indexes the same slot's pages in
    each of them. Block 0 is reserved as the null block and never
    allocated."""

    def __init__(self, layout: PagedLayout, n_slots: int):
        self.layout = layout
        self.n_slots = n_slots
        self.table = np.zeros((n_slots, layout.blocks_per_slot), np.int32)
        self._n_alloc = np.zeros(n_slots, np.int32)   # allocated per slot
        self._free: List[int] = list(range(layout.n_blocks - 1, 0, -1))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.layout.n_blocks - 1 - len(self._free)

    def alloc_tokens(self, slot: int) -> int:
        """KV positions resident for ``slot`` (allocated blocks × block
        length) — the block-rounded footprint the traffic model reads."""
        return int(self._n_alloc[slot]) * self.layout.block_len

    def can_fit(self, n_tokens: int) -> bool:
        return blocks_for(n_tokens, self.layout.block_len) <= len(self._free)

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot`` to hold ``n_tokens`` positions; False if the pool
        is exhausted (caller backpressures the request queue)."""
        need = blocks_for(n_tokens, self.layout.block_len)
        if need > self.layout.blocks_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceed "
                f"{self.layout.view_len} (blocks_per_slot × block_len)")
        have = int(self._n_alloc[slot])
        if need <= have:
            return True
        if need - have > len(self._free):
            return False
        for j in range(have, need):
            self.table[slot, j] = self._free.pop()
        self._n_alloc[slot] = need
        return True

    def release(self, slot: int) -> None:
        """Return every block of ``slot`` to the free list."""
        n = int(self._n_alloc[slot])
        for j in range(n):
            self._free.append(int(self.table[slot, j]))
            self.table[slot, j] = 0
        self._n_alloc[slot] = 0

    def rows(self, slots) -> np.ndarray:
        """Table restricted to ``slots``: other rows are nulled so a
        batched prefill cannot clobber live pages of mid-decode slots."""
        out = np.zeros_like(self.table)
        for s in slots:
            out[s] = self.table[s]
        return out

    def as_array(self) -> jnp.ndarray:
        return jnp.asarray(self.table)
