"""Three-term roofline model from the compiled dry-run (DESIGN §6).

    compute    t_c = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     t_m = HLO_bytes / (chips × HBM_bw)
    collective t_x = Σ wire_bytes(algo) / (chips × link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
NOT in cost_analysis, so we parse the post-SPMD HLO (``compiled.as_text()``)
and sum operand/result sizes of every collective op with ring-algorithm
factors:  all-reduce 2(n−1)/n · S,  all-gather/reduce-scatter (n−1)/n · S,
all-to-all (n−1)/n · S,  collective-permute 1 · S   (per participant).

Hardware model (TPU v5e-like, from the assignment): 197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Hardware constants (assignment-provided)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        return len([t for t in first.split(",") if t])
    m2 = re.search(r"replica_groups=\[(\d+)(?:,(\d+))*\]<=", line)
    return 2


@dataclass
class CollectiveStats:
    """Per-kind totals. wire_bytes are GLOBAL (summed over participants)."""
    counts: Dict[str, int] = field(default_factory=dict)
    wire_bytes: Dict[str, float] = field(default_factory=dict)
    ops: List[Tuple[str, float, int]] = field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scan post-SPMD HLO for collective ops and sum algorithm-adjusted
    wire bytes."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # op kind appears as `= <shape> <kind>(` or `<kind>-start(`
        kind = None
        for k in _COLL_KINDS:
            if re.search(rf"\s{k}(-start)?\(", s):
                kind = k
                break
        if kind is None:
            continue
        shapes = _SHAPE_RE.findall(s)
        if not shapes:
            continue
        # first shape token on the line is the result; the rest (inside the
        # operand parens) are operands. Tuples repeat shapes; take the result
        # for all-gather (output-sized traffic), operands otherwise.
        lhs, rhs = s.split("(", 1)
        res_shapes = _SHAPE_RE.findall(lhs)
        opd_shapes = _SHAPE_RE.findall(rhs.split("),")[0] + ")")
        res_b = sum(_shape_bytes(d, x) for d, x in res_shapes)
        opd_b = sum(_shape_bytes(d, x) for d, x in opd_shapes)
        n = max(2, _group_size(s))
        if kind == "all-reduce":
            per = 2.0 * (n - 1) / n * opd_b
        elif kind == "all-gather":
            per = (n - 1) / n * res_b
        elif kind == "reduce-scatter":
            per = (n - 1) / n * opd_b
        elif kind == "all-to-all":
            per = (n - 1) / n * opd_b
        else:  # collective-permute: one hop
            per = float(opd_b)
            n = 1
        total = per * max(1, n)
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.wire_bytes[kind] = stats.wire_bytes.get(kind, 0.0) + total
        stats.ops.append((kind, total, n))
    return stats


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

@dataclass
class Roofline:
    flops: float               # HLO FLOPs, global (sum over chips)
    hbm_bytes: float           # HLO bytes accessed, global
    wire_bytes: float          # collective wire bytes, global
    chips: int
    model_flops: float = 0.0   # 6·N·D (dense) / 6·N_active·D (MoE)
    collectives: Optional[CollectiveStats] = None
    dot_calls: float = 0.0     # dot executions incl. trip counts (remat det.)
    trip_counts: Optional[Dict[str, int]] = None

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term bound that is useful compute:
        t_compute / max(all terms). 1.0 = compute-bound at peak."""
        m = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / m if m > 0 else 0.0

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.flops if self.flops else 0.0

    def row(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.roofline_fraction,
            "model_flops": self.model_flops,
            "hlo_flops": self.flops,
            "useful_ratio": self.useful_flops_ratio,
        }


def from_compiled(compiled, chips: int, *, model_flops: float = 0.0,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Build the roofline from an AOT-compiled executable.

    Uses the hlo_parser cost walker (NOT compiled.cost_analysis(), which
    counts scan bodies once — see analysis/hlo_parser.py). The parsed SPMD
    program is the per-device program; flops/bytes are scaled by ``chips``
    for global totals. Collective wire bytes are already global."""
    from repro.analysis import hlo_parser
    text = hlo_text if hlo_text is not None else compiled.as_text()
    pc = hlo_parser.analyze(text)
    coll = CollectiveStats(counts=dict(pc.coll_counts),
                           wire_bytes=dict(pc.coll_wire))
    rl = Roofline(
        flops=pc.flops * chips,
        hbm_bytes=pc.hbm_bytes * chips,
        wire_bytes=pc.wire_bytes,
        chips=chips,
        model_flops=model_flops,
        collectives=coll,
    )
    rl.dot_calls = pc.dot_calls
    rl.trip_counts = pc.trip_counts
    return rl


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6·N·D)
# ---------------------------------------------------------------------------

def param_count_active(cfg) -> Tuple[float, float]:
    """(total_params, active_params) analytic estimate for 6·N·D."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.padded_vocab
    hd = cfg.resolved_head_dim
    attn = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) \
        + (cfg.n_heads * hd) * d
    if cfg.moe.n_experts > 0:
        e_ff = cfg.moe.d_ff_expert or cfg.d_ff
        expert = 3 * d * e_ff
        n_route = cfg.moe.n_experts
        shared = cfg.moe.n_shared_experts
        ffn_total = (n_route + shared) * expert
        ffn_active = (cfg.moe.top_k + shared) * expert
        dense_extra = cfg.moe.first_k_dense * 3 * d * (cfg.moe.d_ff_dense
                                                       or cfg.d_ff)
        n_moe_layers = L - cfg.moe.first_k_dense
        total = L * attn + n_moe_layers * ffn_total + dense_extra + 2 * V * d
        active = L * attn + n_moe_layers * ffn_active + dense_extra + 2 * V * d
        return float(total), float(active)
    ffn = 3 * d * cfg.d_ff if cfg.d_ff else 8 * d * d  # ssm-ish fallback
    total = L * (attn + ffn) + (V * d if cfg.tie_embeddings else 2 * V * d)
    return float(total), float(total)


def model_flops(cfg, n_tokens: int, kind: str = "train") -> float:
    """6·N·D for training; 2·N·D for one forward (prefill/decode)."""
    _, active = param_count_active(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * active * n_tokens


def train_mfu(cfg, n_tokens: int, dt_s: float, chips: int = 1) -> float:
    """Model FLOPs utilisation of one training step: the 6·N·D model
    FLOPs actually delivered per second, as a fraction of the chips' peak
    (``PEAK_FLOPS`` each). The trainer publishes this per step as the
    ``train.mfu`` gauge."""
    if dt_s <= 0:
        return 0.0
    return model_flops(cfg, n_tokens, "train") / dt_s / (chips * PEAK_FLOPS)
