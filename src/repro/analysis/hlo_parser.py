"""Post-SPMD HLO cost walker (DESIGN §6).

``compiled.cost_analysis()`` visits every instruction ONCE — a while loop
(jax.lax.scan over layers) is counted as a single iteration, so depth-L
models are under-counted by ~L×. This walker parses ``compiled.as_text()``
and computes, per computation and with loop trip counts multiplied in:

  * flops       — dot/convolution FLOPs (2·prod(result)·prod(contract)),
  * hbm_bytes   — post-fusion traffic model: every top-level instruction
                  reads its operands and writes its result once (a fusion
                  is one instruction ⇒ its internals are VMEM-resident,
                  exactly the TPU model),
  * wire_bytes  — ring-algorithm collective bytes (incl. collectives that
                  live *inside* scan bodies, which a flat regex pass would
                  count once).

Trip counts are recovered from the loop condition computation: scan lowers
to a counter compared against a constant; we take the max integer constant
in the condition computation.

First-order model: elementwise flops are ignored (dots dominate
transformer steps); parameter/constant/gte/tuple/bitcast ops are
traffic-free.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_OP_RE = re.compile(r"^\s*([\w\-]+)\((.*)$", re.S)


def _split_inst(line: str):
    """'%name = <result-type> op(operands), attrs' → parts, or None.

    Handles tuple result types with /*index=N*/ comments (which contain '='
    and defeat naive regexes)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") and not s[:1].isalpha():
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].strip().lstrip("%")
    rhs = s[eq + 3:]
    if rhs.startswith("("):          # tuple result type: matching paren
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    result, rest = rhs[:i + 1], rhs[i + 1:]
                    break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        result, rest = rhs[:sp], rhs[sp:]
    m = _OP_RE.match(rest)
    if not m:
        return None
    return name, result, m.group(1), m.group(2)
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)="
    r"(?:%?([\w\.\-]+)|\{([^}]*)\})")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPND_RE = re.compile(r"%([\w\.\-]+)")

_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id",
               "iota", "copy-start", "copy-done"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start", "reduce-scatter-start"}
_CALLERS = {"fusion", "call", "conditional", "reduce", "map", "scatter",
            "sort", "select-and-scatter", "reduce-window", "custom-call"}


def _dims(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",") if d]


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


def _shapes_bytes(text: str) -> int:
    return sum(_DTYPE_BYTES.get(d, 0) * _prod(_dims(x))
               for d, x in _SHAPE_RE.findall(text))


@dataclass
class Inst:
    name: str
    op: str
    result_bytes: int
    result_dims: List[int]
    operand_names: List[str]
    operand_inline_bytes: int   # operands with inline shapes (older HLO)
    attrs: str
    called: List[str]
    cond: Optional[str] = None  # while ops: the condition computation


@dataclass
class Computation:
    name: str
    insts: List[Inst] = field(default_factory=list)
    symbols: Dict[str, Tuple[int, List[int]]] = field(default_factory=dict)
    max_const: int = 1


@dataclass
class ProgramCost:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    coll_counts: Dict[str, int]
    coll_wire: Dict[str, float]
    dot_calls: float
    trip_counts: Dict[str, int]


def _groups(attrs: str) -> Tuple[int, int]:
    """(group_size, n_groups). One SPMD collective instruction is executed
    by every group simultaneously — global wire bytes scale with both."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]", attrs)
    if m:
        return int(m.group(2)), int(m.group(1))
    m = re.search(r"replica_groups=\{(\{[^}]*\}(?:,\s*\{[^}]*\})*)\}", attrs)
    if m:
        gs = m.group(1).split("},")
        first = gs[0].strip("{} ")
        return len([t for t in first.split(",") if t]), len(gs)
    return 2, 1


def _wire(kind: str, opd_b: int, res_b: int, attrs: str) -> float:
    """Global (all-participant) ring-algorithm wire bytes for one op."""
    n, g = _groups(attrs)
    n = max(2, n)
    base = kind.replace("-start", "")
    if base == "all-reduce":
        per = 2.0 * (n - 1) / n * opd_b
    elif base == "all-gather":
        per = (n - 1) / n * res_b
    elif base in ("reduce-scatter", "all-to-all"):
        per = (n - 1) / n * opd_b
    else:  # collective-permute: one hop per participating device
        return float(opd_b) * g
    return per * n * g


def parse_program(hlo_text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line or line.startswith("HloModule"):
            continue
        stripped = line.strip()
        if not line.startswith("  ") and "{" in line and "->" in line:
            is_entry = stripped.startswith("ENTRY")
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if is_entry:
                    entry = cur.name
            continue
        if stripped == "}" or cur is None:
            continue
        for c in _CONST_RE.findall(line):
            cur.max_const = max(cur.max_const, int(c))
        parts = _split_inst(line)
        if parts is None:
            continue
        name, result, op, rest = parts
        res_shapes = _SHAPE_RE.findall(result)
        res_b = _shapes_bytes(result)
        res_dims = _dims(res_shapes[0][1]) if res_shapes else []
        # split "operands) , attrs": find the paren close at depth 0
        depth, cut = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    cut = i
                    break
        opd_text, attrs = rest[:cut], rest[cut:]
        opd_names = _OPND_RE.findall(opd_text)
        opd_inline = _shapes_bytes(opd_text)
        called = []
        for g1, g2 in _CALLED_RE.findall(attrs):
            if g1:
                called.append(g1)
            elif g2:
                called += [t.strip().lstrip("%") for t in g2.split(",")]
        cm = re.search(r"condition=%?([\w\.\-]+)", attrs)
        inst = Inst(name, op, res_b, res_dims, opd_names, opd_inline,
                    attrs, called, cond=cm.group(1) if cm else None)
        cur.insts.append(inst)
        cur.symbols[name] = (res_b, res_dims)
    return comps, entry


def analyze(hlo_text: str) -> ProgramCost:
    comps, entry = parse_program(hlo_text)
    glob: Dict[str, Tuple[int, List[int]]] = {}
    for c in comps.values():
        glob.update(c.symbols)

    def opnd_bytes(comp: Computation, inst: Inst) -> int:
        if inst.operand_inline_bytes:
            return inst.operand_inline_bytes
        total = 0
        for nm in inst.operand_names:
            rec = comp.symbols.get(nm) or glob.get(nm)
            if rec:
                total += rec[0]
        return total

    def opnd_dims(comp: Computation, inst: Inst, idx: int) -> List[int]:
        if idx >= len(inst.operand_names):
            return []
        nm = inst.operand_names[idx]
        rec = comp.symbols.get(nm) or glob.get(nm)
        return rec[1] if rec else []

    memo: Dict[str, Tuple[float, float, float, float, Dict[str, float],
                          Dict[str, int]]] = {}
    trip_counts: Dict[str, int] = {}

    def cost(name: str):
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return (0.0, 0.0, 0.0, 0.0, {}, {})
        memo[name] = (0.0, 0.0, 0.0, 0.0, {}, {})  # cycle guard
        fl = hb = wb = dc = 0.0
        cw: Dict[str, float] = {}
        cc: Dict[str, int] = {}
        for inst in comp.insts:
            opd_b = opnd_bytes(comp, inst)
            if inst.op == "while":
                # trip count = the loop bound constant, which lives in the
                # CONDITION computation (never the body — bodies contain
                # unrelated large index constants)
                trip = 1
                if inst.cond and inst.cond in comps:
                    trip = comps[inst.cond].max_const
                trip_counts[inst.name] = trip
                for cn in inst.called:
                    f2, h2, w2, d2, cw2, cc2 = cost(cn)
                    fl += trip * f2
                    hb += trip * h2
                    wb += trip * w2
                    dc += trip * d2
                    for k, v in cw2.items():
                        cw[k] = cw.get(k, 0.0) + trip * v
                    for k, v in cc2.items():
                        cc[k] = cc.get(k, 0) + trip * v
                continue
            if inst.op in _CALLERS:
                for cn in inst.called:
                    f2, h2, w2, d2, cw2, cc2 = cost(cn)
                    fl += f2            # flops inside fusions count
                    wb += w2
                    dc += d2
                    for k, v in cw2.items():
                        cw[k] = cw.get(k, 0.0) + v
                    for k, v in cc2.items():
                        cc[k] = cc.get(k, 0) + v
                    # no hbm from callee: fusion internals are VMEM-resident
            if inst.op == "dot":
                contract = 1
                cm = _CONTRACT_RE.search(inst.attrs)
                lhs = opnd_dims(comp, inst, 0)
                if cm and lhs:
                    for ci in _dims(cm.group(1)):
                        if ci < len(lhs):
                            contract *= lhs[ci]
                fl += 2.0 * _prod(inst.result_dims) * contract
                dc += 1
            elif inst.op == "convolution":
                fl += 2.0 * _prod(inst.result_dims) * max(1, opd_b // 4)
            if inst.op not in _NO_TRAFFIC:
                hb += opd_b + inst.result_bytes
            if inst.op in _COLLECTIVES:
                kind = inst.op.replace("-start", "")
                w = _wire(inst.op, opd_b, inst.result_bytes, inst.attrs)
                wb += w
                cw[kind] = cw.get(kind, 0.0) + w
                cc[kind] = cc.get(kind, 0) + 1
        memo[name] = (fl, hb, wb, dc, cw, cc)
        return memo[name]

    if not entry and comps:
        entry = list(comps)[-1]
    fl, hb, wb, dc, cw, cc = cost(entry)
    return ProgramCost(flops=fl, hbm_bytes=hb, wire_bytes=wb,
                       coll_counts=cc, coll_wire=cw, dot_calls=dc,
                       trip_counts=trip_counts)
