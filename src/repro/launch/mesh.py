"""Production mesh definitions — thin forwarder.

Mesh construction is owned by :mod:`repro.dist.sharding` (built through
the version-portable :mod:`repro.dist.compat` layer); this module keeps
the historical ``repro.launch.mesh`` import path alive. Both are
FUNCTIONS, not module-level constants — importing never touches jax
device state (required so smoke tests see 1 device while the dry-run
sees 512)."""
from __future__ import annotations

from repro.dist.sharding import (  # noqa: F401
    make_local_mesh,
    make_production_mesh,
)
