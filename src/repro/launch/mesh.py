"""Production mesh definitions (deliverable (e)).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees 512)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh():
    """Single-device mesh with the same axis names (tests / CPU training)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
