"""Training launcher (deliverable (b) driver).

CPU-scale by default: pick an arch (full or smoke config), a small batch,
and run the fault-tolerant Trainer on the synthetic C4 pipeline. On a real
TPU fleet the same entrypoint runs under `jax.distributed` with the
production mesh; here the mesh is the single-device local mesh.

Usage:
  python -m repro.launch.train --arch llama_60m --smoke --steps 200
  python -m repro.launch.train --arch llama_60m --smoke --mode dense   # baseline
  python -m repro.launch.train --arch yi_34b --smoke --optimizer adam8bit
  python -m repro.launch.train --arch llama_60m --smoke --steps 20 \
      --update-mode per_layer --layer-timing \
      --metrics-out /tmp/train.jsonl --trace-out /tmp/train_trace.json
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.configs.base import (OptimizerConfig, ShardingConfig, TrainConfig,
                                ParamConfig)
from repro.models import registry
from repro.obs import trace as obs_trace
from repro.train.trainer import Trainer


def build_train_config(args) -> TrainConfig:
    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    if args.mode:
        cfg = dataclasses.replace(
            cfg, param=dataclasses.replace(cfg.param, mode=args.mode))
    if args.exec_mode:
        cfg = dataclasses.replace(
            cfg, param=dataclasses.replace(cfg.param, exec_mode=args.exec_mode))
    if args.delta is not None:
        cfg = dataclasses.replace(
            cfg, param=dataclasses.replace(cfg.param, delta=args.delta))
    if args.rank is not None:
        cfg = dataclasses.replace(
            cfg, param=dataclasses.replace(cfg.param, rank=args.rank))
    oc = OptimizerConfig(name=args.optimizer, lr=args.lr,
                         warmup_steps=max(1, args.steps // 10),
                         total_steps=args.steps)
    sc = ShardingConfig(remat=args.remat, grad_accum=args.grad_accum,
                        update_mode=args.update_mode, fsdp=args.fsdp)
    return TrainConfig(model=cfg, optim=oc, sharding=sc, seed=args.seed,
                       global_batch=args.batch, seq_len=args.seq,
                       steps=args.steps, log_every=args.log_every,
                       ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama_60m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--mode", default=None,
                    choices=[None, "dense", "lowrank", "sltrain", "relora"])
    ap.add_argument("--exec-mode", default=None,
                    choices=[None, "dense", "sparse", "fused"],
                    help="sltrain execution mode: dense densify (XLA "
                         "baseline), sparse factored gather (decode), "
                         "fused Pallas tile kernels (training)")
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adam8bit", "galore_adamw"])
    ap.add_argument("--delta", type=float, default=None)
    ap.add_argument("--rank", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--update-mode", default="global",
                    choices=["global", "per_layer"],
                    help="per_layer = layer-wise backward sweep with "
                         "in-sweep optimizer updates (repro.train.perlayer"
                         "; O(layer) grad residency, the paper's Appendix-F"
                         " memory path)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--metrics-out", default=None,
                    help="append registry snapshot JSONL lines here (one "
                         "per log interval; repro.obs.metrics)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON of per-step spans "
                         "(data/dispatch/sync; repro.obs.trace)")
    ap.add_argument("--layer-timing", action="store_true",
                    help="with --update-mode per_layer: record per-layer "
                         "update wall time via ordered io_callback into "
                         "train.perlayer.layer_update_ms")
    ap.add_argument("--jax-profile-dir", default=None,
                    help="also record a jax.profiler trace into this dir "
                         "for the duration of the run")
    ap.add_argument("--chaos", default=None,
                    help="fault-injection spec 'kind@step[:arg],...' "
                         "(repro.resilience.chaos), e.g. 'kill@3' or "
                         "'nonfinite@5,straggler@4:50' — every injected "
                         "fault must end in a verified recovery")
    ap.add_argument("--max-rollbacks", type=int, default=2,
                    help="checkpoint rollbacks tolerated before the "
                         "trainer gives up on a persistent divergence")
    ap.add_argument("--multipod", action="store_true",
                    help="initialize jax.distributed from JAX_* env vars "
                         "(scripts/launch_multipod.sh sets them)")
    ap.add_argument("--use-mesh", action="store_true",
                    help="run under the named local mesh and place state "
                         "via the repro.dist.sharding spec engine")
    ap.add_argument("--fsdp", action="store_true",
                    help="with --use-mesh: additionally shard params and "
                         "optimizer state over the data axis "
                         "(ShardingConfig.fsdp) and pin gradients to the "
                         "sharded layout (reduce-scatter update)")
    args = ap.parse_args(argv)
    if args.use_mesh and args.multipod:
        ap.error("--use-mesh builds the single-process local mesh and "
                 "cannot be combined with --multipod")
    if args.fsdp and not args.use_mesh:
        ap.error("--fsdp shards state via the spec engine and needs "
                 "--use-mesh (or a multipod mesh wired in code)")

    if args.multipod:
        import os
        import jax
        jax.distributed.initialize(
            coordinator_address=os.environ["JAX_COORDINATOR_ADDRESS"],
            num_processes=int(os.environ["JAX_NUM_PROCESSES"]),
            process_id=int(os.environ["JAX_PROCESS_ID"]))

    mesh = None
    if args.use_mesh:
        from repro.dist import sharding as dist_sharding
        mesh = dist_sharding.make_local_mesh()

    chaos = None
    if args.chaos:
        from repro.resilience.chaos import ChaosEngine
        chaos = ChaosEngine.parse(args.chaos, seed=args.seed)

    tc = build_train_config(args)
    trace = obs_trace.Trace(
        enabled=bool(args.trace_out or args.jax_profile_dir),
        jax_profile_dir=args.jax_profile_dir)
    trace.start()
    trainer = Trainer(tc, mesh=mesh, trace=trace,
                      metrics_out=args.metrics_out,
                      layer_timing=args.layer_timing,
                      chaos=chaos, max_rollbacks=args.max_rollbacks)
    state = trainer.run()
    trace.stop()
    print(f"final step {state.step}: "
          f"loss={trainer.metrics_history[-1]['loss']:.4f}")
    if args.trace_out:
        n = trace.export(args.trace_out)
        print(f"trace: {n} events -> {args.trace_out}")
    return trainer


if __name__ == "__main__":
    main()
