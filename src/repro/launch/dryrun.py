import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import: jax locks device count at first init.
"""Multi-pod dry-run (deliverable (e)): AOT-lower + compile train_step /
serve_step for every (architecture × input-shape) cell on the production
meshes — 16×16 = 256 chips single-pod and 2×16×16 = 512 chips multi-pod —
with 512 placeholder host devices. No arrays are ever allocated: parameters,
optimizer state, caches and batches are all ShapeDtypeStructs.

Per cell it prints/records compiled.memory_analysis() (proves fit),
cost_analysis() (FLOPs/bytes for §Roofline) and the collective wire bytes
parsed from the post-SPMD HLO (DESIGN §6).

Usage:
  python -m repro.launch.dryrun --arch yi_34b --cell train_4k
  python -m repro.launch.dryrun --arch yi_34b --cell train_4k --multi-pod
  python -m repro.launch.dryrun --all --out results.jsonl
"""
import argparse
import json
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding

from repro.analysis import roofline as roofline_lib
from repro.configs.base import SHAPE_CELLS, OptimizerConfig, ShapeCell
from repro.dist import sharding as sharding_lib
from repro.dist.sharding import make_production_mesh, named_shardings as _ns
from repro.launch import specs
from repro.models import registry
from repro.optim import optimizers
from repro.train import step as step_lib


def lower_cell(arch: str, cell: ShapeCell, *, multi_pod: bool = False,
               remat: str = "none", mesh=None, cfg_overrides=None,
               verbose: bool = True, with_compiled: bool = False,
               fsdp: bool = False):
    """Lower + compile one (arch × cell) on the production mesh. Returns a
    result dict (memory analysis, cost analysis, roofline terms); with
    ``with_compiled=True`` returns ``(result, compiled)`` so diagnostics
    (scripts/top_collectives.py) can walk the post-SPMD HLO text.

    ``fsdp=True`` additionally shards params + optimizer state over the
    data axis (ShardingConfig.fsdp semantics: fsdp_axes=("data",)) and
    pins the train step's gradients to that layout — the ISSUE-8
    llama_7b placement gate drives this path."""
    cfg_overrides = dict(cfg_overrides or {})
    param_mode = cfg_overrides.pop("param_mode", None)
    cfg = registry.get_config(arch, **cfg_overrides)
    if param_mode:  # keep the arch's rank/delta/alpha, swap the mode only
        import dataclasses
        cfg = dataclasses.replace(
            cfg, param=dataclasses.replace(cfg.param, mode=param_mode))
    api = registry.get_api(cfg)
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    chips = mesh.devices.size
    batch_axes = tuple(a for a in sharding_lib.BATCH_AXES
                       if a in mesh.axis_names)

    fsdp_axes = ("data",) if fsdp else ()
    params_abs, consts_abs = api.init(cfg, key=None)      # abstract init
    p_specs = sharding_lib.param_specs(params_abs, mesh,
                                       fsdp_axes=fsdp_axes)
    c_specs = sharding_lib.param_specs(consts_abs, mesh,
                                       fsdp_axes=fsdp_axes)

    t0 = time.time()
    if cell.kind in ("train", "prefill"):
        batch_abs = specs.input_specs(cfg, cell.global_batch, cell.seq_len,
                                      abstract=True)
        b_specs = sharding_lib.batch_specs(batch_abs, mesh, batch_axes)
        if cell.kind == "train":
            oc = OptimizerConfig()
            opt = optimizers.make(oc)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            o_specs = sharding_lib.opt_state_specs(opt_abs, p_specs, mesh,
                                                   fsdp_axes=fsdp_axes)
            fn = step_lib.make_train_step(
                cfg, api, opt, remat=remat,
                grad_specs=p_specs if fsdp else None)
            jfn = jax.jit(
                fn,
                in_shardings=(_ns(mesh, p_specs), _ns(mesh, o_specs),
                              _ns(mesh, c_specs), _ns(mesh, b_specs)),
                out_shardings=(_ns(mesh, p_specs), _ns(mesh, o_specs), None),
            )
            with mesh:
                lowered = jfn.lower(params_abs, opt_abs, consts_abs, batch_abs)
        else:  # prefill: loss-less forward
            def prefill(params, consts, batch):
                logits, _ = api.apply(cfg, params, consts, batch, remat=remat)
                return logits
            jfn = jax.jit(
                prefill,
                in_shardings=(_ns(mesh, p_specs), _ns(mesh, c_specs),
                              _ns(mesh, b_specs)),
            )
            with mesh:
                lowered = jfn.lower(params_abs, consts_abs, batch_abs)
        n_tokens = cell.global_batch * cell.seq_len
        kind = "train" if cell.kind == "train" else "prefill"
    else:  # decode / long_decode: one new token against a seq_len cache
        cache_abs = api.init_cache(cfg, cell.global_batch, cell.seq_len,
                                   abstract=True)
        k_specs = sharding_lib.cache_specs(cache_abs, mesh,
                                           batch_axes=batch_axes)
        tokens_abs, index_abs = specs.decode_inputs(
            cfg, cell.global_batch, cell.seq_len, abstract=True)
        b_spec = sharding_lib.batch_specs({"t": tokens_abs}, mesh,
                                          batch_axes)["t"]
        fn = step_lib.make_serve_step(cfg, api)
        jfn = jax.jit(
            fn,
            in_shardings=(_ns(mesh, p_specs), _ns(mesh, c_specs),
                          NamedSharding(mesh, b_spec), _ns(mesh, k_specs),
                          None),
            out_shardings=(NamedSharding(mesh, b_spec), None,
                           _ns(mesh, k_specs)),
        )
        with mesh:
            lowered = jfn.lower(params_abs, consts_abs, tokens_abs,
                                cache_abs, index_abs)
        n_tokens = cell.global_batch
        kind = "decode"

    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    mf = roofline_lib.model_flops(cfg, n_tokens, kind)
    rl = roofline_lib.from_compiled(compiled, chips, model_flops=mf)

    result = {
        "arch": arch, "cell": cell.name, "multi_pod": multi_pod,
        "chips": chips, "remat": remat, "fsdp": fsdp,
        "compile_s": round(compile_s, 1),
        "bytes_per_device": {
            "argument": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak": int(getattr(mem, "argument_size_in_bytes", 0))
            + int(getattr(mem, "temp_size_in_bytes", 0)),
        },
        "roofline": rl.row(),
        "collectives": {
            "counts": rl.collectives.counts,
            "wire_GB": {k: v / 1e9 for k, v in
                        rl.collectives.wire_bytes.items()},
        },
    }
    if verbose:
        bpd = result["bytes_per_device"]
        r = result["roofline"]
        print(f"[{arch} × {cell.name} | {'2-pod' if multi_pod else '1-pod'}"
              f" {chips}c] compile {compile_s:.0f}s  "
              f"args {bpd['argument']/2**30:.2f}GiB "
              f"temp {bpd['temp']/2**30:.2f}GiB/dev")
        print(f"  roofline: t_c={r['t_compute_s']:.4f}s "
              f"t_m={r['t_memory_s']:.4f}s t_x={r['t_collective_s']:.4f}s "
              f"-> {r['bottleneck']}-bound, frac={r['roofline_fraction']:.2f} "
              f"useful={r['useful_ratio']:.2f}")
        print(f"  collectives: {result['collectives']['counts']}")
    if with_compiled:
        return result, compiled
    return result


def iter_cells(archs=None):
    archs = archs or registry.ARCHS
    for arch in archs:
        for cell in SHAPE_CELLS:
            if registry.cell_applicable(arch, cell.name):
                yield arch, cell
            else:
                print(f"[skip] {arch} × {cell.name}: "
                      f"{registry.skip_reason(arch, cell.name)}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--sp", action="store_true",
                    help="sequence-shard the residual stream (§Perf it.2)")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params/opt-state over the data axis too")
    ap.add_argument("--mode", default=None,
                    help="override param mode (dense/lowrank/sltrain)")
    ap.add_argument("--tag", default=None, help="label stored in the result")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    args = ap.parse_args(argv)

    cells = {c.name: c for c in SHAPE_CELLS}
    todo = []
    if args.all:
        for arch, cell in iter_cells():
            todo.append((arch, cell, False))
            todo.append((arch, cell, True))
    else:
        assert args.arch and args.cell, "--arch and --cell (or --all)"
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            todo.append((args.arch, cells[args.cell], mp))

    overrides = {}
    if args.sp:
        overrides["seq_shard_activations"] = True
    if args.mode:
        overrides["param_mode"] = args.mode

    failures = []
    for arch, cell, mp in todo:
        try:
            res = lower_cell(arch, cell, multi_pod=mp, remat=args.remat,
                             cfg_overrides=overrides or None,
                             fsdp=args.fsdp)
            if args.tag:
                res["tag"] = args.tag
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(res) + "\n")
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, cell.name, mp, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print(f"\nall {len(todo)} dry-run cells compiled OK")


if __name__ == "__main__":
    main()
