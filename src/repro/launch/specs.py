"""Input stand-ins: ShapeDtypeStruct specs for the dry-run (no allocation)
and concrete random batches for smoke tests / real training.

Modality frontends are STUBS (DESIGN §5): whisper gets precomputed frame
embeddings, paligemma gets precomputed patch embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCell


def input_specs(cfg: ModelConfig, batch: int, seq: int, abstract: bool = True,
                key=None):
    """Training/prefill batch for one model. Returns a dict pytree."""
    dt = jnp.dtype(cfg.dtype)

    def tok(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, jnp.int32)
        k = key if key is not None else jax.random.PRNGKey(0)
        return jax.random.randint(k, shape, 0, cfg.vocab_size, jnp.int32)

    def emb(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        k = key if key is not None else jax.random.PRNGKey(1)
        return (jax.random.normal(k, shape, jnp.float32) * 0.02).astype(dt)

    batch_dict = {"tokens": tok((batch, seq))}
    if cfg.family == "whisper":
        batch_dict["frames"] = emb((batch, cfg.encoder_seq, cfg.d_model))
    elif cfg.family == "vlm":
        n = min(cfg.n_patches, seq)
        batch_dict["patches"] = emb((batch, n, cfg.d_model))
    return batch_dict


def decode_inputs(cfg: ModelConfig, batch: int, cache_len: int,
                  abstract: bool = True, key=None):
    """(tokens, index) for one serve_step against a cache of cache_len."""
    if abstract:
        tokens = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        index = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        k = key if key is not None else jax.random.PRNGKey(2)
        tokens = jax.random.randint(k, (batch, 1), 0, cfg.vocab_size, jnp.int32)
        index = jnp.int32(cache_len - 1)
    return tokens, index


def cell_batch(cfg: ModelConfig, cell: ShapeCell, abstract: bool = True):
    """Materialize the assigned shape cell for this arch."""
    if cell.kind in ("train", "prefill"):
        return input_specs(cfg, cell.global_batch, cell.seq_len, abstract)
    return None  # decode cells use decode_inputs + the model's init_cache
