"""Serving launcher: restore a checkpoint (or init) and serve batched
requests through the continuous-batching engine.

Usage:
  python -m repro.launch.serve --arch llama_60m --smoke --requests 8
  python -m repro.launch.serve --arch llama_60m --smoke --sparse-decode
  python -m repro.launch.serve --arch llama_60m --smoke --paged --block-len 8
  python -m repro.launch.serve --arch llama_60m --smoke --paged --stagger
  python -m repro.launch.serve --arch llama_60m --smoke --paged \
      --attn-kernel paged
  python -m repro.launch.serve --arch llama_60m --smoke --paged \
      --stream --prefix-sharing
  python -m repro.launch.serve --arch llama_60m --smoke --paged --stream \
      --metrics-out /tmp/serve.jsonl --trace-out /tmp/serve_trace.json
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import registry
from repro.obs import trace as obs_trace
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama_60m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--sparse-decode", action="store_true",
                    help="factored SLTrain decode (DESIGN §3 beyond-paper)")
    ap.add_argument("--exec-mode", default=None,
                    choices=("dense", "sparse", "fused", "quant"),
                    help="explicit SLTrain serve execution mode (supersedes "
                         "--sparse-decode; 'quant' requires --quant-ckpt)")
    ap.add_argument("--quant-ckpt", default=None,
                    help="load a calibrated int8 quant artifact "
                         "(python -m repro.quant.calibrate) instead of a "
                         "training checkpoint; defaults --exec-mode to "
                         "'quant'")
    ap.add_argument("--paged", action="store_true",
                    help="block-paged KV cache with batched prefill and "
                         "per-slot decode positions (serve/kv.py)")
    ap.add_argument("--block-len", type=int, default=16,
                    help="tokens per KV block (paged only)")
    ap.add_argument("--attn-kernel", default=None,
                    choices=("gather", "paged"),
                    help="paged attention read path: 'gather' materializes "
                         "the per-slot K/V view, 'paged' streams blocks "
                         "through the Pallas paged-attention kernels "
                         "(kernels/paged_attention.py; requires --paged). "
                         "Default: the config's choice ('paged' on a paged "
                         "engine, auto-fallback to 'gather' otherwise)")
    ap.add_argument("--stagger", action="store_true",
                    help="submit requests one engine step apart (exercises "
                         "diverging per-slot positions)")
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching: stamp Poisson arrival ticks "
                         "on the requests and serve via run_stream — "
                         "admission happens inside the decode loop "
                         "(requires --paged)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="copy-on-write prefix sharing: admissions whose "
                         "prompt matches a resident block-aligned prefix "
                         "attach those pages read-only and prefill only "
                         "the suffix (requires --paged)")
    ap.add_argument("--use-mesh", action="store_true",
                    help="place weights/cache via repro.dist.sharding on "
                         "the named local mesh")
    ap.add_argument("--metrics-out", default=None,
                    help="append one registry snapshot JSONL line here at "
                         "the end of the run (repro.obs.metrics)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON (Perfetto-loadable) "
                         "with engine phase spans + per-request tick "
                         "lifecycle lanes (repro.obs.trace)")
    ap.add_argument("--jax-profile-dir", default=None,
                    help="also record a jax.profiler trace into this dir "
                         "for the duration of the run")
    ap.add_argument("--chaos", default=None,
                    help="fault-injection spec (repro.resilience.chaos), "
                         "e.g. 'stall@4:64' — freezes one active slot for "
                         "64 ticks at tick 4; the engine must drain with "
                         "zero wedged requests")
    ap.add_argument("--deadline-ticks", type=int, default=None,
                    help="cancel any request not completed within this "
                         "many engine ticks of its arrival "
                         "(status='timed_out', pages released)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="wall-clock completion deadline per request, ms")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission queue cap: submits past it are shed "
                         "with status='rejected' instead of queued")
    ap.add_argument("--quant-fallback", action="store_true",
                    help="with --exec-mode quant: degrade to the bf16 "
                         "sparse path (warn + serve) when the artifact "
                         "fails validation, instead of refusing to start")
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    if args.quant_ckpt and cfg.param.mode != "sltrain":
        import dataclasses
        cfg = dataclasses.replace(
            cfg, param=dataclasses.replace(cfg.param, mode="sltrain"))
    api = registry.get_api(cfg)
    exec_mode = args.exec_mode
    if args.quant_ckpt:
        # the artifact carries BOTH trees (error-folded B/A params and the
        # int8 tile-CSR consts) — no init-then-restore template needed
        from repro.ckpt.checkpoint import load_quant_artifact
        params, consts, qman = load_quant_artifact(args.quant_ckpt)
        exec_mode = exec_mode or "quant"
        print(f"quant artifact: {args.quant_ckpt} "
              f"({qman['extra'].get('n_matrices', '?')} matrices)")
    else:
        params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    if args.ckpt_dir:
        from repro.ckpt.checkpoint import CheckpointManager
        cm = CheckpointManager(args.ckpt_dir)
        tree, _ = cm.restore({"params": params}, allow_config_change=True)
        params = tree["params"]

    mesh = None
    if args.use_mesh:
        from repro.dist import sharding as dist_sharding
        mesh = dist_sharding.make_local_mesh()
    if (args.stream or args.prefix_sharing) and not args.paged:
        ap.error("--stream/--prefix-sharing require --paged")
    trace = obs_trace.Trace(
        enabled=bool(args.trace_out or args.jax_profile_dir),
        jax_profile_dir=args.jax_profile_dir)
    trace.start()
    chaos = None
    if args.chaos:
        from repro.resilience.chaos import ChaosEngine
        chaos = ChaosEngine.parse(args.chaos)
    eng = ServeEngine(cfg, params, consts, n_slots=args.slots,
                      max_len=args.max_len,
                      sparse_decode=args.sparse_decode,
                      exec_mode=exec_mode, mesh=mesh,
                      paged=args.paged, block_len=args.block_len,
                      attn_kernel=args.attn_kernel,
                      prefix_sharing=args.prefix_sharing,
                      trace=trace, max_queue=args.max_queue,
                      deadline_ticks=args.deadline_ticks,
                      deadline_ms=args.deadline_ms,
                      tick_hook=chaos.serve_hook if chaos else None,
                      quant_fallback=args.quant_fallback)
    if chaos is not None:
        chaos.bind(eng.obs)
    rng = np.random.default_rng(0)
    prompts = []
    shared = rng.integers(3, cfg.vocab_size, size=16).tolist()
    for i in range(args.requests):
        plen = int(rng.integers(2, 8))
        tail = rng.integers(3, cfg.vocab_size, size=plen).tolist()
        # with sharing on, give the workload something to share: half the
        # prompts open with one common (block-alignable) system prefix
        prompts.append(shared + tail if args.prefix_sharing and i % 2 == 0
                       else tail)
    t0 = time.perf_counter()
    reqs = []
    if args.stream:
        arrivals = np.cumsum(rng.poisson(2.0, size=len(prompts)))
        reqs = [eng.submit(p, max_new_tokens=args.new_tokens, arrival=int(a))
                for p, a in zip(prompts, arrivals)]
        stats = eng.run_stream()
    else:
        if args.stagger:
            it = iter(prompts)
            reqs.append(eng.submit(next(it), max_new_tokens=args.new_tokens))
            for p in it:
                eng.step()
                reqs.append(eng.submit(p, max_new_tokens=args.new_tokens))
        else:
            reqs = [eng.submit(p, max_new_tokens=args.new_tokens)
                    for p in prompts]
        stats = eng.run_until_drained()
    dt = time.perf_counter() - t0
    # terminal-status accounting: the engine never silently loses a
    # request — every one ends done/rejected/timed_out (failed only when
    # the step budget ran out, which these bounded runs never hit)
    assert all(r.status in ("done", "rejected", "timed_out") for r in reqs) \
        and not stats["exhausted"], \
        ([(r.uid, r.status) for r in reqs], stats["exhausted"])
    degraded = args.chaos or args.deadline_ticks is not None \
        or args.deadline_ms is not None or args.max_queue is not None
    if not degraded:
        assert len(stats["completed"]) == len(reqs), \
            (len(stats["completed"]), len(reqs))
    total_toks = sum(len(r.out) for r in reqs)
    mode = f"paged/{eng.cfg.attn_kernel}" if args.paged else "legacy"
    if args.stream:
        mode += "/stream"
    print(f"served {len(reqs)} requests, {total_toks} tokens in {dt:.2f}s "
          f"({total_toks/dt:.1f} tok/s, {stats['decode_steps']} decode steps,"
          f" {eng.dispatches['prefill']} prefill dispatches, {mode},"
          f" exec_mode={eng.cfg.param.exec_mode})")
    if args.prefix_sharing:
        pt = eng.prefill_traffic
        print(f"  prefix sharing: {pt['tokens_shared']}/{pt['tokens_total']} "
              "prompt tokens attached from resident pages (never "
              "recomputed or rewritten)")
    if args.stream:
        # both TTFT units, from the engine's registry histograms: ticks
        # (deterministic dispatch clock) and wall ms (what an SLO means);
        # shed/timed-out requests may never see a first token — skip them
        ht = eng.obs.histogram("serve.ttft_ticks")
        hw = eng.obs.histogram("serve.ttft_wall_ms")
        tt = sorted(r.t_first - r.arrival for r in reqs
                    if r.t_first is not None)
        if tt:
            print(f"  TTFT: p50={ht.percentile(50):.0f} ticks "
                  f"(max={tt[-1]}) | p50={hw.percentile(50):.1f}ms "
                  f"p99={hw.percentile(99):.1f}ms wall")
    if eng.timed_out or eng.rejected:
        print(f"  resilience: {stats['summary']} "
              f"({len(eng.timed_out)} past deadline, "
              f"{len(eng.rejected)} shed at submit)")
    for r in reqs[:4]:
        print(f"  req {r.uid}: prompt {r.prompt} -> {r.out}")
    trace.stop()
    if args.metrics_out:
        eng.obs.write_jsonl(args.metrics_out,
                            extra={"run": "serve", "arch": args.arch,
                                   "requests": len(reqs)})
        print(f"  metrics snapshot appended to {args.metrics_out}")
    if args.trace_out:
        n = trace.export(args.trace_out)
        print(f"  trace: {n} events -> {args.trace_out}")


if __name__ == "__main__":
    main()
