from repro.data.pipeline import SyntheticC4, unigram_entropy  # noqa: F401
