"""Deterministic synthetic C4-like token pipeline (DESIGN §2).

Offline container ⇒ no HuggingFace C4; we build a *learnable* surrogate: a
seeded order-1 Markov source with low-rank transition structure, packed
into fixed-length sequences exactly like a real pretraining pipeline (doc
boundaries marked with EOS, no padding waste).

Properties the framework relies on:
  * deterministic in (seed, host_id, num_hosts, step) — restart-safe, and
    the *global* batch is identical for any host count (elasticity),
  * host-sharded: each host generates only its slice of the global batch,
  * checkpointable: ``state_dict()``/``restore()`` round-trips the cursor,
  * cheap: the Markov walk is vectorized across the batch; per step cost is
    O(seq · batch) table lookups (the transition top-k table is precomputed
    once at init).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

_TOPK = 32          # sampled support per transition row
_MAX_STATES = 4096  # Markov states = min(vocab, this); token -> state by mod


@dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self) -> Dict[str, int]:
        return {"seed": int(self.seed), "step": int(self.step)}

    @staticmethod
    def from_dict(d) -> "DataState":
        return DataState(int(d["seed"]), int(d["step"]))


class SyntheticC4:
    """Markov-chain token source with document packing.

    The transition matrix is low-rank (rank 16) so that a small LM can
    actually *learn* it — examples/quickstart.py shows the loss dropping
    well below the unigram entropy.
    """

    EOS = 1

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 42, host_id: int = 0, num_hosts: int = 1,
                 mean_doc_len: int = 192):
        assert global_batch % num_hosts == 0, "global batch must shard by host"
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_hosts
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.mean_doc_len = mean_doc_len
        self.state = DataState(seed=seed, step=0)

        # Precompute the top-k transition table once (chunked, init-time).
        rng = np.random.default_rng(np.uint64(seed))
        r = 16
        n_states = min(vocab_size, _MAX_STATES)
        U = rng.standard_normal((n_states, r)).astype(np.float32)
        V = rng.standard_normal((r, vocab_size)).astype(np.float32)
        bias = (rng.standard_normal((vocab_size,)) * 0.5).astype(np.float32)
        ids = np.empty((n_states, _TOPK), dtype=np.int32)
        cdf = np.empty((n_states, _TOPK), dtype=np.float32)
        for lo in range(0, n_states, 512):
            hi = min(lo + 512, n_states)
            logits = U[lo:hi] @ V + bias            # (chunk, vocab)
            top = np.argpartition(logits, -_TOPK, axis=1)[:, -_TOPK:]
            lt = np.take_along_axis(logits, top, axis=1) / 1.2
            p = np.exp(lt - lt.max(axis=1, keepdims=True))
            p /= p.sum(axis=1, keepdims=True)
            ids[lo:hi] = top.astype(np.int32)
            cdf[lo:hi] = np.cumsum(p, axis=1)
        cdf[:, -1] = 1.0 + 1e-6
        self._ids, self._cdf, self._n_states = ids, cdf, n_states

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return self.state.to_dict()

    def restore(self, d) -> None:
        st = DataState.from_dict(d)
        assert st.seed == self.state.seed, "restoring a different data seed"
        self.state = st

    def skip(self, n: int) -> None:
        """Advance the cursor ``n`` batches without generating them —
        the trainer's divergence rollback resumes from the checkpoint but
        takes a DIFFERENT data path past the batch that blew up."""
        self.state = DataState(self.state.seed, self.state.step + int(n))

    # -- generation ----------------------------------------------------------
    def _global_rows(self, rng: np.random.Generator, n_rows: int) -> np.ndarray:
        """Vectorized Markov walk: all rows advance one position per loop
        iteration; doc boundaries are per-row countdowns emitting EOS."""
        s, b = self.seq_len, n_rows
        out = np.empty((b, s), dtype=np.int32)
        tok = rng.integers(3, self.vocab_size, size=b).astype(np.int32)
        remain = np.maximum(8, rng.exponential(self.mean_doc_len, size=b)
                            ).astype(np.int64)
        u = rng.random((s, b), dtype=np.float32)
        u_new = rng.integers(3, self.vocab_size, size=(s, b)).astype(np.int32)
        for i in range(s):
            at_eos = remain <= 0
            tok = np.where(at_eos, self.EOS, tok)
            out[:, i] = tok
            # next token: sample from the state's top-k CDF
            st = tok % self._n_states
            choice = (u[i][:, None] > self._cdf[st]).sum(axis=1)
            nxt = self._ids[st, choice]
            # rows that just emitted EOS start a new doc with a fresh token
            nxt = np.where(at_eos, u_new[i], nxt)
            remain = np.where(at_eos,
                              np.maximum(8, (u[i] * 2 * self.mean_doc_len)
                                         .astype(np.int64)),
                              remain - 1)
            tok = nxt.astype(np.int32)
        return out

    def next_batch(self) -> Dict[str, np.ndarray]:
        """Local shard of the global batch for this step: {tokens (b, s)}."""
        step = self.state.step
        rng = np.random.default_rng(
            np.uint64(self.state.seed * 1_000_003 + step))
        rows = self._global_rows(rng, self.global_batch)
        lo = self.host_id * self.local_batch
        self.state = DataState(self.state.seed, step + 1)
        return {"tokens": rows[lo:lo + self.local_batch]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


def unigram_entropy(vocab_size: int, seed: int = 42, samples: int = 8192) -> float:
    """Empirical unigram cross-entropy of the source — the 'no-learning'
    baseline the quickstart compares against."""
    ds = SyntheticC4(vocab_size, 256, max(1, samples // 256), seed=seed)
    toks = ds.next_batch()["tokens"].reshape(-1)
    counts = np.bincount(toks, minlength=vocab_size).astype(np.float64) + 1e-9
    p = counts / counts.sum()
    return float(-(p * np.log(p)).sum())
