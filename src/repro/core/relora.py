"""ReLoRA baseline (paper baseline [32]): W = W0 + (alpha/r) B A with
periodic merge-and-restart. W0 is dense (ReLoRA is NOT parameter efficient —
that is the paper's point); B, A are the only trainable factors between
merges, so optimizer state is factored-sized."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_params(key, d_in: int, d_out: int, rank: int, dtype=jnp.bfloat16):
    k_w, k_a = jax.random.split(key)
    std = float(np.sqrt(2.0 / (d_in + d_out)))
    return {
        "W0": (jax.random.normal(k_w, (d_in, d_out), jnp.float32) * std).astype(dtype),
        "B": jnp.zeros((d_in, rank), dtype=dtype),
        "A": (jax.random.uniform(k_a, (rank, d_out), jnp.float32,
                                 minval=-np.sqrt(6.0 / d_in),
                                 maxval=np.sqrt(6.0 / d_in))).astype(dtype),
    }


def abstract_params(d_in: int, d_out: int, rank: int, dtype=jnp.bfloat16):
    sds = jax.ShapeDtypeStruct
    return {"W0": sds((d_in, d_out), dtype), "B": sds((d_in, rank), dtype),
            "A": sds((rank, d_out), dtype)}


def rl_matmul(x, params, scale: float):
    y = x @ params["W0"]
    return y + ((x @ params["B"]) @ params["A"]) * jnp.asarray(scale, x.dtype)


def merge(params, key, scale: float):
    """Merge the adaptor into W0 and restart the factors (ReLoRA period end).

    Stack-agnostic: factors may carry leading layer-stack dims (L, ..., d, r)
    from scan-over-layers. The caller must also reset the Adam moments for
    B/A (repro.train.trainer._make_relora_merge does)."""
    d_in = params["B"].shape[-2]
    BA = jnp.einsum("...ir,...rj->...ij",
                    params["B"].astype(jnp.float32),
                    params["A"].astype(jnp.float32)) * scale
    W0 = params["W0"] + BA.astype(params["W0"].dtype)
    lim = float(np.sqrt(6.0 / d_in))
    A = jax.random.uniform(key, params["A"].shape, jnp.float32,
                           minval=-lim, maxval=lim).astype(params["A"].dtype)
    return {"W0": W0, "B": jnp.zeros_like(params["B"]), "A": A}
