"""Fixed random sparse support for SLTrain (paper §3.2, §3.3).

The support I is sampled once at init and never learned. We provide

  * ``sample_support`` — (rows, cols) int32 arrays, either iid-uniform
    (paper) or row-balanced (each row gets exactly k = round(delta*d_out)
    entries; better Prop.1 coverage and perfectly balanced shards/tiles).
  * ``nnz_for`` — deterministic nnz so dry-run ShapeDtypeStructs agree with
    real init.
  * ``tile_layout`` — re-orders a support into the tile-CSR layout consumed
    by the Pallas kernels (entries bucketed by (tile_r, tile_c), padded to
    the per-tile max with sentinel entries whose value contribution is 0).
  * ``partition_support`` — deterministic split of the support by shard
    owner along either matrix dim, for TP/EP sharding of V (DESIGN §4).

Everything here runs at *init time* on host (numpy), keyed by an integer
seed, so elastic restore can re-derive identical supports on a new mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

# Kernel tile edge (the Pallas sl_matmul/sddmm 128×128 VMEM tiles). Single
# source of truth for every tile-shape computation outside the kernels
# themselves (tile_cap, ops.prepare_tiles, sltrain.abstract_params) — the
# abstract dry-run twin only matches concrete init if all of them agree.
TILE = 128

# Above this many elements the row-balanced sampler stops materializing the
# full (d_in, d_out) random-key matrix and draws it in row blocks instead
# (same PRNG stream, so both branches produce identical supports — see
# ``sample_support``). Module-level so tests can shrink it to exercise the
# blocked branch on small shapes.
DENSE_KEYS_ELEMS = 1 << 26


def nnz_for(d_in: int, d_out: int, delta: float, kind: str = "row_balanced") -> int:
    """Number of nonzeros; deterministic function of the shape and delta."""
    if kind == "row_balanced":
        k = max(1, int(round(delta * d_out)))
        return d_in * k
    return max(1, int(round(delta * d_in * d_out)))


def tile_cap(d_in: int, d_out: int, delta: float,
             kind: str = "row_balanced", tile_r: int = TILE,
             tile_c: int = TILE) -> int:
    """Deterministic per-tile capacity for the tile-CSR layout.

    ``tile_layout``'s data-dependent pad (max realized count per tile)
    breaks two consumers: the no-alloc dry-run cannot know it without
    sampling, and ``stack_layers`` cannot stack per-layer tile consts whose
    realized pads differ. This bound depends only on (shape, delta, kind):
    mean entries per (tile_r × tile_c) tile plus an 8·sqrt(mean) + 16
    sub-Gaussian tail margin (per-tile overflow odds ~exp(-30); the fused
    init re-samples the support on the host in that astronomically rare
    case), clamped to the per-tile combinatorial maximum and rounded up to
    a multiple of 8 for TPU-friendly strides.
    """
    rows_in_tile = min(tile_r, d_in)
    cols_in_tile = min(tile_c, d_out)
    if kind == "row_balanced":
        k = max(1, int(round(delta * d_out)))
        mean = rows_in_tile * k * (cols_in_tile / d_out)
        hard = rows_in_tile * min(k, cols_in_tile)
    else:
        nnz = nnz_for(d_in, d_out, delta, kind)
        mean = nnz * (rows_in_tile * cols_in_tile) / (d_in * d_out)
        hard = rows_in_tile * cols_in_tile
    cap = int(np.ceil(mean + 8.0 * np.sqrt(mean) + 16.0))
    cap = min(cap, int(hard))
    return max(8, ((cap + 7) // 8) * 8)


def _row_balanced_cols(rng: np.random.Generator, d_in: int, d_out: int,
                       k: int) -> np.ndarray:
    """Per-row k-subset sampling via argpartition of random keys.

    Row blocks bound peak memory to O(block · d_out) instead of the full
    d_in·d_out key matrix (the old fallback was an O(d_in) python loop of
    ``rng.choice`` — minutes at 7B shapes). PCG64 fills C-order from a
    sequential stream, so consecutive block draws reproduce the single
    full-matrix draw bit-for-bit: both branches are seed-deterministic AND
    agree with each other (regression-tested across the threshold).
    """
    block = d_in if d_in * d_out <= DENSE_KEYS_ELEMS else \
        max(1, DENSE_KEYS_ELEMS // d_out)
    out = np.empty((d_in, k), dtype=np.int32)
    for i0 in range(0, d_in, block):
        b = min(block, d_in - i0)
        keys = rng.random((b, d_out), dtype=np.float32)
        if k >= d_out:          # degenerate: every column is in the support
            out[i0:i0 + b] = np.arange(d_out, dtype=np.int32)
        else:
            out[i0:i0 + b] = np.argpartition(keys, k, axis=1)[:, :k]
    return out


def sample_support(
    seed: int, d_in: int, d_out: int, delta: float, kind: str = "row_balanced"
) -> Tuple[np.ndarray, np.ndarray]:
    """Sample the fixed support. Returns (rows, cols) int32, row-major sorted."""
    rng = np.random.default_rng(np.uint64(seed))
    if kind == "row_balanced":
        k = max(1, int(round(delta * d_out)))
        # per-row choice without replacement via partial argsort of random
        # keys; blocked above DENSE_KEYS_ELEMS with an identical stream
        cols = _row_balanced_cols(rng, d_in, d_out, k)
        cols.sort(axis=1)
        rows = np.repeat(np.arange(d_in, dtype=np.int32), k)
        return rows, cols.reshape(-1)
    # iid uniform support (paper's sampling): draw flat indices w/o replacement
    nnz = nnz_for(d_in, d_out, delta, kind)
    total = d_in * d_out
    flat = rng.choice(total, size=nnz, replace=False)
    flat.sort()
    rows = (flat // d_out).astype(np.int32)
    cols = (flat % d_out).astype(np.int32)
    return rows, cols


def tile_layout(
    rows: np.ndarray,
    cols: np.ndarray,
    d_in: int,
    d_out: int,
    tile_r: int = TILE,
    tile_c: int = TILE,
    pad: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Bucket support entries by (row-tile, col-tile) for the Pallas kernels.

    Returns (perm, local_rc, tile_counts, pad_per_tile) where
      * perm        int32[n_tiles * pad] — index into the original (rows, cols,
                    values) arrays, with -1 for padding slots,
      * local_rc    int32[n_tiles * pad, 2] — (row, col) local to the tile;
                    padding slots point at (0, 0),
      * tile_counts int32[nt_r, nt_c] — real entries per tile,
      * pad_per_tile — the uniform per-tile capacity. By default the max
                    realized count rounded up to a multiple of 8 (data-
                    dependent); pass ``pad`` (e.g. from :func:`tile_cap`) to
                    force a deterministic capacity — raises ``ValueError``
                    when the realized max exceeds it so callers can
                    re-sample the support on host.
    """
    nt_r = (d_in + tile_r - 1) // tile_r
    nt_c = (d_out + tile_c - 1) // tile_c
    t_id = (rows // tile_r).astype(np.int64) * nt_c + (cols // tile_c)
    order = np.argsort(t_id, kind="stable")
    t_sorted = t_id[order]
    counts = np.bincount(t_sorted, minlength=nt_r * nt_c).astype(np.int32)
    max_count = int(counts.max()) if counts.size else 0
    if pad is None:
        pad = max(8, ((max_count + 7) // 8) * 8)
    elif max_count > pad:
        raise ValueError(
            f"tile_layout: realized per-tile max {max_count} exceeds the "
            f"requested capacity {pad} — re-sample the support")
    n_tiles = nt_r * nt_c
    perm = np.full((n_tiles, pad), -1, dtype=np.int32)
    local = np.zeros((n_tiles, pad, 2), dtype=np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for t in range(n_tiles):
        c = counts[t]
        if c == 0:
            continue
        idx = order[starts[t] : starts[t] + c]
        perm[t, :c] = idx
        local[t, :c, 0] = rows[idx] % tile_r
        local[t, :c, 1] = cols[idx] % tile_c
    return perm.reshape(-1), local.reshape(-1, 2), counts.reshape(nt_r, nt_c), pad


def partition_support(
    rows: np.ndarray,
    cols: np.ndarray,
    n_shards: int,
    dim_size: int,
    axis: str = "col",
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Split support by shard owner along rows ("row") or cols ("col").

    Returns (rows_sh, cols_sh, valid_mask, per_shard) with shapes
    (n_shards, per_shard); indices are *local* to the shard along the
    partitioned axis. Padded slots have mask=0 and index 0 (their values are
    forced to 0 so they contribute nothing). Deterministic: elastic restore
    with a different n_shards re-derives partitions from the same support.
    """
    key = rows if axis == "row" else cols
    shard_sz = dim_size // n_shards
    owner = np.minimum(key // shard_sz, n_shards - 1)
    per = np.bincount(owner, minlength=n_shards)
    cap = int(per.max()) if per.size else 1
    cap = max(8, ((cap + 7) // 8) * 8)
    r = np.zeros((n_shards, cap), dtype=np.int32)
    c = np.zeros((n_shards, cap), dtype=np.int32)
    m = np.zeros((n_shards, cap), dtype=bool)
    for s in range(n_shards):
        sel = np.nonzero(owner == s)[0]
        rs, cs = rows[sel], cols[sel]
        if axis == "row":
            rs = rs - s * shard_sz
        else:
            cs = cs - s * shard_sz
        r[s, : sel.size] = rs
        c[s, : sel.size] = cs
        m[s, : sel.size] = True
    return r, c, m, cap
