"""Low-rank linear baseline (paper baseline [24]): W = (alpha/r) B A."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_params(key, d_in: int, d_out: int, rank: int, dtype=jnp.bfloat16):
    k_a, k_b = jax.random.split(key)
    lim = float(np.sqrt(6.0 / d_in))
    return {
        # Both factors random at init (pretraining-from-scratch, not LoRA
        # adaptation: zero-B would make W identically 0 with no signal).
        "B": jax.random.uniform(k_b, (d_in, rank), jnp.float32,
                                minval=-lim, maxval=lim).astype(dtype),
        "A": jax.random.uniform(k_a, (rank, d_out), jnp.float32,
                                minval=-lim, maxval=lim).astype(dtype),
    }


def abstract_params(d_in: int, d_out: int, rank: int, dtype=jnp.bfloat16):
    sds = jax.ShapeDtypeStruct
    return {"B": sds((d_in, rank), dtype), "A": sds((rank, d_out), dtype)}


def lr_matmul(x, params, scale: float):
    # (x @ B) @ A ordering: never materializes the d_in×d_out product.
    return ((x @ params["B"]) @ params["A"]) * jnp.asarray(scale, x.dtype)
