"""Memory estimator reproducing the paper's Appendix-F accounting.

Conventions (paper §5.1 "Memory cost estimation"):
  * bf16 params/moments: 2 bytes; 1 G = 1e9 bytes.
  * SLTrain indices: int64 = 8 B/entry (paper). We also expose the int32
    convention this framework actually uses on TPU (DESIGN §3).
  * Adam optimizer state = 2x trainable parameter count.
  * GaLore: moments live in the projected space (project the smaller matrix
    dim to rank r), plus the stored projection matrices.

The estimator consumes a *matrix inventory*: every weight matrix in the
model, flagged ``adapted`` if the method reparameterizes it (all attention +
MLP linears; embeddings/norms/head stay dense — paper §5.1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core import support as support_lib


@dataclass(frozen=True)
class MatrixInfo:
    name: str
    d_in: int
    d_out: int
    adapted: bool = True
    count: int = 1          # e.g. n_layers or n_layers*n_experts


@dataclass(frozen=True)
class MemoryEstimate:
    method: str
    param_count: float
    trainable_count: float
    param_bytes: float
    optim_bytes: float

    @property
    def total_bytes(self) -> float:
        return self.param_bytes + self.optim_bytes

    def gb(self, x: float) -> float:
        return x / 1e9

    def as_dict(self) -> Dict[str, float]:
        return {
            "method": self.method,
            "params_M": self.param_count / 1e6,
            "trainable_M": self.trainable_count / 1e6,
            "param_G": self.gb(self.param_bytes),
            "optim_G": self.gb(self.optim_bytes),
            "total_G": self.gb(self.total_bytes),
        }


def estimate(inventory: List[MatrixInfo], method: str, *, rank: int = 128,
             delta: float = 0.03, dtype_bytes: int = 2, index_bytes: int = 8,
             support_kind: str = "iid", galore_rank: int | None = None
             ) -> MemoryEstimate:
    galore_rank = galore_rank or rank
    base = sum(m.d_in * m.d_out * m.count for m in inventory if not m.adapted)
    dense_adapted = sum(m.d_in * m.d_out * m.count for m in inventory if m.adapted)
    lr_adapted = sum((m.d_in + m.d_out) * rank * m.count
                     for m in inventory if m.adapted)

    if method == "full":
        p = base + dense_adapted
        return MemoryEstimate(method, p, p, p * dtype_bytes, 2 * p * dtype_bytes)

    if method == "lowrank":
        p = base + lr_adapted
        return MemoryEstimate(method, p, p, p * dtype_bytes, 2 * p * dtype_bytes)

    if method == "relora":
        # stores W0 (dense) + factors; moments only on trainable (factors+base)
        p = base + dense_adapted + lr_adapted
        t = base + lr_adapted
        return MemoryEstimate(method, p, t, p * dtype_bytes, 2 * t * dtype_bytes)

    if method == "galore":
        p = base + dense_adapted
        proj = 0.0
        moments = 2.0 * base
        for m in inventory:
            if not m.adapted:
                continue
            small, big = min(m.d_in, m.d_out), max(m.d_in, m.d_out)
            r = min(galore_rank, small)
            proj += small * r * m.count
            moments += 2.0 * r * big * m.count
        return MemoryEstimate(method, p, p, p * dtype_bytes,
                              (moments + proj) * dtype_bytes)

    if method == "sltrain":
        nnz = sum(support_lib.nnz_for(m.d_in, m.d_out, delta, support_kind)
                  * m.count for m in inventory if m.adapted)
        t = base + lr_adapted + nnz
        param_bytes = t * dtype_bytes + nnz * index_bytes
        return MemoryEstimate(method, t, t, param_bytes, 2 * t * dtype_bytes)

    raise ValueError(f"unknown method {method!r}")


def llama_inventory(n_layers: int, d_model: int, d_ff: int, vocab: int,
                    n_heads: int = 0, n_kv_heads: int = 0, head_dim: int = 0,
                    tie_embeddings: bool = False) -> List[MatrixInfo]:
    """Inventory for a LLaMA-family model (SwiGLU MLP, untied head by default
    — matches the paper's 60M–7B accounting)."""
    hd = head_dim or (d_model // max(1, n_heads))
    nh = n_heads or (d_model // hd)
    nkv = n_kv_heads or nh
    inv = [
        MatrixInfo("embed", vocab, d_model, adapted=False),
        MatrixInfo("wq", d_model, nh * hd, count=n_layers),
        MatrixInfo("wk", d_model, nkv * hd, count=n_layers),
        MatrixInfo("wv", d_model, nkv * hd, count=n_layers),
        MatrixInfo("wo", nh * hd, d_model, count=n_layers),
        MatrixInfo("gate", d_model, d_ff, count=n_layers),
        MatrixInfo("up", d_model, d_ff, count=n_layers),
        MatrixInfo("down", d_ff, d_model, count=n_layers),
    ]
    if not tie_embeddings:
        inv.append(MatrixInfo("lm_head", d_model, vocab, adapted=False))
    return inv


# The paper's LLaMA pretraining configs (GaLore/ReLoRA lineage).
PAPER_LLAMA = {
    "60m": dict(n_layers=8, d_model=512, d_ff=1376, vocab=32000, n_heads=8, rank=128),
    "130m": dict(n_layers=12, d_model=768, d_ff=2048, vocab=32000, n_heads=12, rank=256),
    "350m": dict(n_layers=24, d_model=1024, d_ff=2736, vocab=32000, n_heads=16, rank=256),
    "1b": dict(n_layers=24, d_model=2048, d_ff=5461, vocab=32000, n_heads=32, rank=512),
    "7b": dict(n_layers=32, d_model=4096, d_ff=11008, vocab=32000, n_heads=32, rank=1024),
}


def paper_table8(size: str, delta: float = 0.03) -> Dict[str, Dict[str, float]]:
    """Reproduce Table 8 (memory breakdown) for one paper model size."""
    cfg = dict(PAPER_LLAMA[size])
    rank = cfg.pop("rank")
    inv = llama_inventory(**cfg)
    out = {}
    for method in ("full", "lowrank", "relora", "galore", "sltrain"):
        out[method] = estimate(inv, method, rank=rank, delta=delta).as_dict()
    return out
