"""Memory estimator reproducing the paper's Appendix-F accounting.

Conventions (paper §5.1 "Memory cost estimation"):
  * bf16 params/moments: 2 bytes; 1 G = 1e9 bytes.
  * SLTrain indices: int64 = 8 B/entry (paper). We also expose the int32
    convention this framework actually uses on TPU (DESIGN §3).
  * Adam optimizer state = 2x trainable parameter count.
  * GaLore: moments live in the projected space (project the smaller matrix
    dim to rank r), plus the stored projection matrices.

The estimator consumes a *matrix inventory*: every weight matrix in the
model, flagged ``adapted`` if the method reparameterizes it (all attention +
MLP linears; embeddings/norms/head stay dense — paper §5.1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core import support as support_lib


@dataclass(frozen=True)
class MatrixInfo:
    name: str
    d_in: int
    d_out: int
    adapted: bool = True
    count: int = 1          # e.g. n_layers or n_layers*n_experts


@dataclass(frozen=True)
class MemoryEstimate:
    method: str
    param_count: float
    trainable_count: float
    param_bytes: float
    optim_bytes: float

    @property
    def total_bytes(self) -> float:
        return self.param_bytes + self.optim_bytes

    def gb(self, x: float) -> float:
        return x / 1e9

    def as_dict(self) -> Dict[str, float]:
        return {
            "method": self.method,
            "params_M": self.param_count / 1e6,
            "trainable_M": self.trainable_count / 1e6,
            "param_G": self.gb(self.param_bytes),
            "optim_G": self.gb(self.optim_bytes),
            "total_G": self.gb(self.total_bytes),
        }


def estimate(inventory: List[MatrixInfo], method: str, *, rank: int = 128,
             delta: float = 0.03, dtype_bytes: int = 2, index_bytes: int = 8,
             support_kind: str = "iid", galore_rank: int | None = None
             ) -> MemoryEstimate:
    galore_rank = galore_rank or rank
    base = sum(m.d_in * m.d_out * m.count for m in inventory if not m.adapted)
    dense_adapted = sum(m.d_in * m.d_out * m.count for m in inventory if m.adapted)
    lr_adapted = sum((m.d_in + m.d_out) * rank * m.count
                     for m in inventory if m.adapted)

    if method == "full":
        p = base + dense_adapted
        return MemoryEstimate(method, p, p, p * dtype_bytes, 2 * p * dtype_bytes)

    if method == "lowrank":
        p = base + lr_adapted
        return MemoryEstimate(method, p, p, p * dtype_bytes, 2 * p * dtype_bytes)

    if method == "relora":
        # stores W0 (dense) + factors; moments only on trainable (factors+base)
        p = base + dense_adapted + lr_adapted
        t = base + lr_adapted
        return MemoryEstimate(method, p, t, p * dtype_bytes, 2 * t * dtype_bytes)

    if method == "galore":
        p = base + dense_adapted
        proj = 0.0
        moments = 2.0 * base
        for m in inventory:
            if not m.adapted:
                continue
            small, big = min(m.d_in, m.d_out), max(m.d_in, m.d_out)
            r = min(galore_rank, small)
            proj += small * r * m.count
            moments += 2.0 * r * big * m.count
        return MemoryEstimate(method, p, p, p * dtype_bytes,
                              (moments + proj) * dtype_bytes)

    if method == "sltrain":
        nnz = sum(support_lib.nnz_for(m.d_in, m.d_out, delta, support_kind)
                  * m.count for m in inventory if m.adapted)
        t = base + lr_adapted + nnz
        param_bytes = t * dtype_bytes + nnz * index_bytes
        return MemoryEstimate(method, t, t, param_bytes, 2 * t * dtype_bytes)

    raise ValueError(f"unknown method {method!r}")


def llama_inventory(n_layers: int, d_model: int, d_ff: int, vocab: int,
                    n_heads: int = 0, n_kv_heads: int = 0, head_dim: int = 0,
                    tie_embeddings: bool = False) -> List[MatrixInfo]:
    """Inventory for a LLaMA-family model (SwiGLU MLP, untied head by default
    — matches the paper's 60M–7B accounting)."""
    hd = head_dim or (d_model // max(1, n_heads))
    nh = n_heads or (d_model // hd)
    nkv = n_kv_heads or nh
    inv = [
        MatrixInfo("embed", vocab, d_model, adapted=False),
        MatrixInfo("wq", d_model, nh * hd, count=n_layers),
        MatrixInfo("wk", d_model, nkv * hd, count=n_layers),
        MatrixInfo("wv", d_model, nkv * hd, count=n_layers),
        MatrixInfo("wo", nh * hd, d_model, count=n_layers),
        MatrixInfo("gate", d_model, d_ff, count=n_layers),
        MatrixInfo("up", d_model, d_ff, count=n_layers),
        MatrixInfo("down", d_ff, d_model, count=n_layers),
    ]
    if not tie_embeddings:
        inv.append(MatrixInfo("lm_head", d_model, vocab, adapted=False))
    return inv


# ---------------------------------------------------------------------------
# Training-state estimator: gradients + optimizer transients (ISSUE 4)
#
# The base `estimate` reproduces Appendix F's params+optimizer accounting;
# this extension adds the two residency terms `update_mode` actually moves:
#   * gradient residency — global mode materializes the full trainable
#     gradient tree before the update; per_layer holds one layer group's
#     grads at a time (repro.train.perlayer),
#   * optimizer transients — the f32 m/v working set the 8-bit update
#     dequantizes into (adamw keeps f32 moments as persistent state, so its
#     transient term is 0; its cost shows up in optim_bytes instead).
# Conventions follow the paper (bf16 = dtype_bytes for params/grads/
# moments, int64 indices by default; pass index_bytes=4 for the int32
# layout this framework ships on device).
# ---------------------------------------------------------------------------

def _per_copy_trainable(m: MatrixInfo, method: str, rank: int, delta: float,
                        support_kind: str) -> float:
    """Trainable parameter count of ONE copy of one inventory matrix."""
    if not m.adapted:
        return m.d_in * m.d_out
    if method in ("full", "galore"):
        return m.d_in * m.d_out
    if method == "lowrank":
        return (m.d_in + m.d_out) * rank
    if method == "relora":
        return m.d_in * m.d_out + (m.d_in + m.d_out) * rank
    if method == "sltrain":
        return (m.d_in + m.d_out) * rank \
            + support_lib.nnz_for(m.d_in, m.d_out, delta, support_kind)
    raise ValueError(method)


@dataclass(frozen=True)
class TrainMemoryEstimate:
    """Appendix-F style steady-state training memory, extended with the
    gradient + optimizer-transient residency terms update_mode moves."""
    method: str
    optimizer: str
    update_mode: str
    param_count: float
    trainable_count: float
    resident_count: float       # co-resident grad group (O(P_t) vs O(P_layer))
    param_bytes: float
    grad_bytes: float
    optim_bytes: float
    transient_bytes: float

    @property
    def total_bytes(self) -> float:
        return (self.param_bytes + self.grad_bytes + self.optim_bytes
                + self.transient_bytes)

    def gb(self, x: float) -> float:
        return x / 1e9

    def as_dict(self) -> Dict[str, float]:
        return {
            "method": self.method, "optimizer": self.optimizer,
            "update_mode": self.update_mode,
            "params_M": self.param_count / 1e6,
            "trainable_M": self.trainable_count / 1e6,
            "resident_M": self.resident_count / 1e6,
            "param_G": self.gb(self.param_bytes),
            "grad_G": self.gb(self.grad_bytes),
            "optim_G": self.gb(self.optim_bytes),
            "transient_G": self.gb(self.transient_bytes),
            "total_G": self.gb(self.total_bytes),
        }


def training_estimate(inventory: List[MatrixInfo], method: str, *,
                      optimizer: str = "adamw",
                      update_mode: str = "global", rank: int = 128,
                      delta: float = 0.03, dtype_bytes: int = 2,
                      index_bytes: int = 8, q_block: int = 256,
                      support_kind: str = "iid", fused_opt: bool = False,
                      galore_rank: int | None = None,
                      moment_bytes: int | None = None) -> TrainMemoryEstimate:
    """Training-state memory = params + grads + optimizer state +
    optimizer f32 transients, under an optimizer × update_mode choice.

    ``update_mode="per_layer"`` (repro.train.perlayer) shrinks the
    co-resident gradient/transient group from the FULL trainable count to
    the largest single update group: max over (one layer's stacked
    matrices, each count==1 leaf such as embed/head) — the engine updates
    the head, then one layer at a time, then the embedding.

    ``fused_opt`` models the Pallas ``adam8bit`` kernel dispatch
    (kernels/adam8bit.py): the dequantized f32 m/v exist only per-tile in
    VMEM, so the HBM transient term drops to 0; the XLA reference
    round-trips the update group's f32 moments through HBM.

    ``moment_bytes`` overrides the per-element size of the adamw m/v
    state. The paper's Appendix-F convention keeps bf16 moments
    (``dtype_bytes``, the default); this framework's adamw
    (optim/optimizers.py) allocates f32 moments regardless of param
    dtype, so gates that compare against MEASURED device residency
    (scripts/fsdp_dryrun.py) pass ``moment_bytes=4``.
    """
    base = estimate(inventory, method, rank=rank, delta=delta,
                    dtype_bytes=dtype_bytes, index_bytes=index_bytes,
                    support_kind=support_kind, galore_rank=galore_rank)
    t = base.trainable_count

    if update_mode == "per_layer":
        layer_group = sum(
            _per_copy_trainable(m, method, rank, delta, support_kind)
            for m in inventory if m.count > 1)
        singles = [
            _per_copy_trainable(m, method, rank, delta, support_kind)
            for m in inventory if m.count == 1]
        resident = max([layer_group] + singles)
    elif update_mode == "global":
        resident = t
    else:
        raise ValueError(f"unknown update_mode {update_mode!r}")

    grad_bytes = resident * dtype_bytes

    if optimizer == "adam8bit":
        # 2 moments × 1 byte codes + f32 per-block scales; the f32 m/v
        # working set exists only while a group updates (VMEM-transient
        # under the fused kernel, HBM-transient under the XLA reference)
        optim_bytes = 2.0 * t * 1 + 2.0 * (t / q_block) * 4
        transient_bytes = 0.0 if fused_opt else 8.0 * resident
    elif optimizer == "adamw":
        # paper convention: bf16 moments (moment_bytes=None keeps it)
        optim_bytes = 2.0 * t * (moment_bytes or dtype_bytes)
        transient_bytes = 0.0
    elif optimizer == "galore_adamw":
        optim_bytes = base.optim_bytes if method == "galore" else \
            estimate(inventory, "galore", rank=rank, delta=delta,
                     dtype_bytes=dtype_bytes,
                     galore_rank=galore_rank).optim_bytes
        transient_bytes = 0.0
    else:
        raise ValueError(f"unknown optimizer {optimizer!r}")

    return TrainMemoryEstimate(
        method, optimizer, update_mode, base.param_count, t, resident,
        base.param_bytes, grad_bytes, optim_bytes, transient_bytes)


def paper_f_reduction(size: str = "7b", *, index_bytes: int = 8
                      ) -> Dict[str, float]:
    """The paper's headline §5.1/Appendix-F claim: SLTrain + 8-bit Adam +
    per-layer updates vs the full-rank AdamW baseline on LLaMA. For 7B
    (δ=0.05, r=1024 — configs/llama_7b.py) this reproduces the ~73%
    total-memory reduction (73.6% with the framework's int32 on-device
    indices, 71.2% with the paper's int64 convention). The lean side
    models the fused-kernel dispatch the per-layer engine uses under
    exec_mode="fused" (f32 moments never in HBM)."""
    cfg = dict(PAPER_LLAMA[size])
    rank = cfg.pop("rank")
    delta = 0.05 if size == "7b" else 0.03
    inv = llama_inventory(**cfg)
    full = training_estimate(inv, "full", optimizer="adamw",
                             update_mode="global", rank=rank, delta=delta)
    lean = training_estimate(inv, "sltrain", optimizer="adam8bit",
                             update_mode="per_layer", rank=rank, delta=delta,
                             index_bytes=index_bytes, fused_opt=True)
    return {"full_G": full.gb(full.total_bytes),
            "lean_G": lean.gb(lean.total_bytes),
            "resident_ratio": lean.resident_count / lean.trainable_count,
            "reduction": 1.0 - lean.total_bytes / full.total_bytes}


# The paper's LLaMA pretraining configs (GaLore/ReLoRA lineage).
PAPER_LLAMA = {
    "60m": dict(n_layers=8, d_model=512, d_ff=1376, vocab=32000, n_heads=8, rank=128),
    "130m": dict(n_layers=12, d_model=768, d_ff=2048, vocab=32000, n_heads=12, rank=256),
    "350m": dict(n_layers=24, d_model=1024, d_ff=2736, vocab=32000, n_heads=16, rank=256),
    "1b": dict(n_layers=24, d_model=2048, d_ff=5461, vocab=32000, n_heads=32, rank=512),
    "7b": dict(n_layers=32, d_model=4096, d_ff=11008, vocab=32000, n_heads=32, rank=1024),
}


def paper_table8(size: str, delta: float = 0.03) -> Dict[str, Dict[str, float]]:
    """Reproduce Table 8 (memory breakdown) for one paper model size."""
    cfg = dict(PAPER_LLAMA[size])
    rank = cfg.pop("rank")
    inv = llama_inventory(**cfg)
    out = {}
    for method in ("full", "lowrank", "relora", "galore", "sltrain"):
        out[method] = estimate(inv, method, rank=rank, delta=delta).as_dict()
    return out
