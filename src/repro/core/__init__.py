"""Core: the paper's contribution — sparse + low-rank (SLTrain) parameterization."""
from repro.core import lowrank, memory, relora, sltrain, support  # noqa: F401
