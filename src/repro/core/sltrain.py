"""SLTrain linear layer: W = (alpha/r) * B @ A  ⊕_I  V   (paper §3.2, Alg. 1).

Two support layouts:

* ``row_balanced`` (default) — each row holds exactly k = round(δ·d_out)
  entries; stored as 2-D ``cols (d_in, k)`` / ``v (d_in, k)`` with the row
  indices IMPLICIT (iota). Halves index memory vs COO (and is 4x smaller
  than the paper's int64 convention), shards naturally along d_in, and
  makes ∇V a single take_along_axis gather. TPU adaptation, DESIGN §3.
* ``iid`` — the paper's uniform sampling, flat COO (rows, cols, v).

Four execution modes (DESIGN §3; the full matrix lives in
``configs.base.ParamConfig``):

* ``dense``  — densify-on-the-fly then one MXU matmul; custom VJP implements
  the paper's eq. (2): dense W is recomputed, never stored as a residual.
* ``sparse`` — beyond-paper factored path for decode: reads only the
  factored bytes from HBM (the decode memory-roofline win).
* ``fused``  — Pallas path for training: sl_matmul densifies each 128×128
  tile in VMEM and feeds it straight to the MXU (forward + dx), sddmm
  gathers dV without the G transient (backward) — the dense W never
  touches HBM at all. Requires tile consts from init
  (``init_params(..., exec_mode="fused")``): int32 {rows_t, cols_t, perm}
  with a DETERMINISTIC per-tile capacity (``support.tile_cap``) so the
  no-alloc dry-run twin and per-layer stacking agree; the trainable ``v``
  stays flat and is gathered/scattered through ``perm`` inside the jit.
* ``quant`` — serve-only post-training path (repro.quant): the sparse
  values run as int8 tile-CSR codes against per-output-channel f32
  scales through the quantized Pallas decode kernel; B/A stay bf16 with
  the quantization error SVD-folded in (SLiM-style). Requires the
  calibrated consts {qv_t, rows_q, cols_q, qscale} from
  ``quant.calibrate``; training rejects this mode (train/step.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import support as support_lib


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

# Seed stride for the host-side re-sample fallback when a sampled support
# exceeds the deterministic tile_cap bound (astronomically rare; see
# support.tile_cap). Deterministic so elastic restore re-derives the same
# final support.
_RESAMPLE_STRIDE = 0x9E3779B1
_RESAMPLE_ATTEMPTS = 16


def prepare_fused_consts(rows, cols, d_in: int, d_out: int, delta: float,
                         support_kind: str, seed: int):
    """Tile consts {rows_t, cols_t, perm} for ``exec_mode="fused"`` at the
    deterministic ``support.tile_cap`` capacity. Returns
    (rows, cols, consts): if the sampled support busts the bound the
    support is re-sampled on host with a deterministically bumped seed and
    the (possibly new) COO arrays are returned alongside the consts."""
    from repro.kernels import ops
    cap = support_lib.tile_cap(d_in, d_out, delta, support_kind)
    for attempt in range(_RESAMPLE_ATTEMPTS):
        try:
            tiles = ops.prepare_tile_consts(rows, cols, d_in, d_out, pad=cap)
            return rows, cols, tiles
        except ValueError:
            rows, cols = support_lib.sample_support(
                seed + (attempt + 1) * _RESAMPLE_STRIDE, d_in, d_out, delta,
                support_kind)
    raise ValueError(
        f"fused tile capacity {cap} too small for ({d_in}, {d_out}, "
        f"delta={delta}, {support_kind}) after {_RESAMPLE_ATTEMPTS} "
        "re-samples — support.tile_cap bound is broken for this shape")


def init_params(key, d_in: int, d_out: int, rank: int, delta: float,
                dtype=jnp.bfloat16, support_kind: str = "row_balanced",
                seed: int = 0, exec_mode: str = "dense"):
    """Init (params, consts). LoRA-style init (paper §3.3): Kaiming-uniform
    A, zero B, v ~ U[-1/sqrt(d_in), 1/sqrt(d_in)].

    ``exec_mode="fused"`` additionally emits the int32 tile consts
    {rows_t, cols_t, perm} the Pallas custom-VJP linear consumes, padded to
    the deterministic ``support.tile_cap`` capacity (abstract dry-run and
    per-layer stacking both rely on shape determinism). The trainable
    params are IDENTICAL across exec modes — same sampled support, same
    flat ``v`` — so checkpoints and optimizer state are layout-independent
    and a dense-mode run with the same seed is token-for-token comparable."""
    k_a, k_v = jax.random.split(key)
    lim_a = float(np.sqrt(6.0 / d_in))
    lim_v = float(1.0 / np.sqrt(d_in))
    rows, cols = support_lib.sample_support(seed, d_in, d_out, delta, support_kind)
    tiles = None
    if exec_mode == "fused":
        rows, cols, tiles = prepare_fused_consts(
            rows, cols, d_in, d_out, delta, support_kind, seed)
    if support_kind == "row_balanced":
        k = cols.shape[0] // d_in
        v_shape = (d_in, k)
        consts = {"cols": jnp.asarray(cols.reshape(d_in, k))}
    else:
        v_shape = (cols.shape[0],)
        consts = {"rows": jnp.asarray(rows), "cols": jnp.asarray(cols)}
    if tiles is not None:
        consts.update(tiles)
    params = {
        "B": jnp.zeros((d_in, rank), dtype=dtype),
        "A": jax.random.uniform(k_a, (rank, d_out), dtype=jnp.float32,
                                minval=-lim_a, maxval=lim_a).astype(dtype),
        "v": jax.random.uniform(k_v, v_shape, dtype=jnp.float32,
                                minval=-lim_v, maxval=lim_v).astype(dtype),
    }
    return params, consts


def abstract_params(d_in: int, d_out: int, rank: int, delta: float,
                    dtype=jnp.bfloat16, support_kind: str = "row_balanced",
                    exec_mode: str = "dense"):
    """ShapeDtypeStruct twin of ``init_params`` for the no-alloc dry-run.
    With ``exec_mode="fused"`` the tile-const shapes are exact (not a
    bound-by-coincidence): concrete init pads every tile to the same
    deterministic ``support.tile_cap`` capacity this computes."""
    nnz = support_lib.nnz_for(d_in, d_out, delta, support_kind)
    sds = jax.ShapeDtypeStruct
    params = {"B": sds((d_in, rank), dtype), "A": sds((rank, d_out), dtype)}
    if support_kind == "row_balanced":
        k = nnz // d_in
        params["v"] = sds((d_in, k), dtype)
        consts = {"cols": sds((d_in, k), jnp.int32)}
    else:
        params["v"] = sds((nnz,), dtype)
        consts = {"rows": sds((nnz,), jnp.int32), "cols": sds((nnz,), jnp.int32)}
    if exec_mode == "fused":
        tile = support_lib.TILE
        nkt = (d_in + tile - 1) // tile
        nnt = (d_out + tile - 1) // tile
        cap = support_lib.tile_cap(d_in, d_out, delta, support_kind)
        for name in ("rows_t", "cols_t", "perm"):
            consts[name] = sds((nkt, nnt, cap), jnp.int32)
    return params, consts


# ---------------------------------------------------------------------------
# Densify
# ---------------------------------------------------------------------------

def _lowrank_dense(B, A, scale):
    return (scale * (B.astype(jnp.float32) @ A.astype(jnp.float32))).astype(B.dtype)


def densify_rb(B, A, v, cols, scale: float):
    """Row-balanced densify: batched per-row scatter at implicit rows."""
    W = _lowrank_dense(B, A, scale)
    d_in = W.shape[0]
    rows = jnp.broadcast_to(jnp.arange(d_in, dtype=jnp.int32)[:, None], cols.shape)
    return W.at[rows, cols].add(v.astype(W.dtype), mode="drop",
                                unique_indices=True)


def densify_coo(B, A, v, rows, cols, scale: float):
    W = _lowrank_dense(B, A, scale)
    return W.at[rows, cols].add(v.astype(W.dtype), mode="drop",
                                unique_indices=True)


# ---------------------------------------------------------------------------
# Dense-mode matmul, row-balanced layout (paper eq. 2 backward)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _sl_matmul_rb(x, B, A, v, cols, scale):
    return x @ densify_rb(B, A, v, cols, scale)


def _sl_matmul_rb_fwd(x, B, A, v, cols, scale):
    # Residuals: factored params + input ONLY (Alg. 1 save_for_backward).
    return x @ densify_rb(B, A, v, cols, scale), (x, B, A, v, cols)


def _grads_from_G_local(xf, dyf, A, B, v, cols, scale):
    """(dB, dA, dv) from a device-local G transient (paper eq. 2).

    G accumulates in f32 (preferred_element_type, NOT a bf16 matmul whose
    result is cast after — that rounds the whole token contraction through
    bf16 first, the PR-1 sparse-decode bug class) so the densify path
    agrees with the fused sddmm kernel, which accumulates its G tiles in
    f32 the same way."""
    G = jnp.matmul(xf.T, dyf, preferred_element_type=jnp.float32)
    dB = (scale * (G @ A.astype(jnp.float32).T)).astype(B.dtype)
    dA = (scale * (B.astype(jnp.float32).T @ G)).astype(A.dtype)
    dv = jnp.take_along_axis(G, cols.astype(jnp.int32), axis=1
                             ).astype(v.dtype)
    return dB, dA, dv


def _grads_distributed(x, dy, A, B, v, cols, scale):
    """Distributed eq. (2) (§Perf it.6/it.8, DESIGN §4).

    Under pjit-auto the token contraction G = xᵀ·dy spans every device, so
    XLA all-reduces the full d_in×d_out f32 transient BEFORE the factor
    projections / support gather — ~0.6 GB of wire per matrix per layer,
    the dominant collective of the whole train step. The token-sum commutes
    with all three consumers of G, so under shard_map we form only a LOCAL
    G slice and psum the r- and k-sized RESULTS instead:
        wire: d_in·d_out·4  →  (d_in+d_out)·r·4 + nnz·4   (~20-30× less).

    Layout (it.8): tokens sharded over (pod, data); d_out sharded over
    "model" — the SAME gather-x + TP-output layout the forward uses, so the
    island does not flip the surrounding rematted matmuls into redundant
    gather-W form (the it.6 lesson: a seq-sharded island de-sharded the
    whole backward region, 5× compute). Each device computes the
    (d_in × d_out/TP) G slice it would have computed as a partial anyway."""
    from repro.dist import compat, sharding as dist_sharding
    mesh = dist_sharding.ambient_mesh()
    if mesh is None or getattr(mesh, "empty", False) or x.ndim < 3:
        return None
    if x.shape[-1] > dy.shape[-1]:
        # island edge would gather the LARGER activation (e.g. the d_ff
        # hidden of a down-projection) — the gather costs more wire than
        # the G all-reduce it avoids (§Perf it.9 napkin math); use the
        # local-G pjit path instead.
        return None
    axes = mesh.axis_names
    bt = tuple(a for a in ("pod", "data") if a in axes)
    import numpy as _np
    nb = int(_np.prod([mesh.shape[a] for a in bt])) if bt else 1
    nm = mesh.shape.get("model", 1) if "model" in axes else 1
    d_in = x.shape[-1]
    d_out = dy.shape[-1]
    r = A.shape[0]
    if not bt or x.shape[0] % nb or d_out % nm or nm <= 1:
        return None
    d_out_loc = d_out // nm
    from jax.sharding import PartitionSpec as P

    def body(xs, dys, A_l, B_r, cols_r):
        xl = xs.reshape(-1, d_in)                       # (Mloc, d_in)
        dyl = dys.reshape(-1, d_out_loc)                # (Mloc, d_out/TP)
        Gl = (xl.T @ dyl).astype(jnp.float32)           # local G slice
        dBl = scale * (Gl @ A_l.astype(jnp.float32).T)  # partial over model
        dAl = scale * (B_r.astype(jnp.float32).T @ Gl)  # partial over bt
        # support gather restricted to this rank's d_out columns
        base = jax.lax.axis_index("model") * d_out_loc
        cl = cols_r.astype(jnp.int32) - base
        ok = (cl >= 0) & (cl < d_out_loc)
        dvl = jnp.take_along_axis(Gl, jnp.clip(cl, 0, d_out_loc - 1), axis=1)
        dvl = jnp.where(ok, dvl, 0.0)
        dB = jax.lax.psum(dBl, bt + ("model",))
        dA = jax.lax.psum(dAl, bt)
        dv = jax.lax.psum(dvl, bt + ("model",))
        return dB, dA, dv

    try:
        dB, dA, dv = compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(bt, None, None), P(bt, None, "model"),
                      P(None, "model"), P(None, None), P(None, None)),
            out_specs=(P(None, None), P(None, "model"), P(None, None)),
            check_vma=False)(x, dy, A, B, cols)
        return dB.astype(B.dtype), dA.astype(A.dtype), dv.astype(v.dtype)
    except Exception:
        return None


def _sl_matmul_rb_bwd(scale, res, dy):
    x, B, A, v, cols = res
    d_in = x.shape[-1]
    d_out = dy.shape[-1]
    # Backward activations in the model dtype (§Perf it.9): upstream ops
    # (norm/softmax backward) hand us f32 cotangents; every collective the
    # partitioner inserts on dy/dx pays 2× for it. bf16 grads are standard.
    dy = dy.astype(x.dtype)
    xf = x.reshape(-1, d_in)
    dyf = dy.reshape(-1, d_out)
    # Distributed eq. (2) when a mesh is ambient (§Perf it.6); else the
    # paper's local-G path. Either way G is a transient, never a residual.
    out = _grads_distributed(x, dy, A, B, v, cols, scale)
    if out is None:
        out = _grads_from_G_local(xf, dyf, A, B, v, cols, scale)
    dB, dA, dv = out
    # dx needs W^T: recompute the densified W (the paper's explicit trade:
    # "we never store it").
    W = densify_rb(B, A, v, cols, scale)
    dx = (dyf @ W.T).reshape(x.shape).astype(x.dtype)
    # NOTE §Perf it.11 (REFUTED): pinning dx seq-sharded here to force a
    # reduce-scatter measured t_x 40.9 -> 43.0 s — the pin creates extra
    # reshards in the surrounding remat region. Left unpinned.
    return dx, dB, dA, dv, None


_sl_matmul_rb.defvjp(_sl_matmul_rb_fwd, _sl_matmul_rb_bwd)


# ---------------------------------------------------------------------------
# Dense-mode matmul, COO layout (paper-faithful iid support)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _sl_matmul_coo(x, B, A, v, support, scale):
    rows, cols = support
    return x @ densify_coo(B, A, v, rows, cols, scale)


def _sl_matmul_coo_fwd(x, B, A, v, support, scale):
    rows, cols = support
    return x @ densify_coo(B, A, v, rows, cols, scale), (x, B, A, v, rows, cols)


def _sl_matmul_coo_bwd(scale, res, dy):
    x, B, A, v, rows, cols = res
    d_in = x.shape[-1]
    d_out = dy.shape[-1]
    xf = x.reshape(-1, d_in)
    dyf = dy.reshape(-1, d_out)
    # f32 accumulation via preferred_element_type (same contract as the
    # row-balanced path's _grads_from_G_local)
    G = jnp.matmul(xf.T, dyf, preferred_element_type=jnp.float32)
    dB = (scale * (G @ A.astype(jnp.float32).T)).astype(B.dtype)
    dA = (scale * (B.astype(jnp.float32).T @ G)).astype(A.dtype)
    dv = G[rows, cols].astype(v.dtype)
    W = densify_coo(B, A, v, rows, cols, scale)
    dx = (dyf @ W.T).reshape(x.shape).astype(x.dtype)
    return dx, dB, dA, dv, None


_sl_matmul_coo.defvjp(_sl_matmul_coo_fwd, _sl_matmul_coo_bwd)


# ---------------------------------------------------------------------------
# Sparse-mode (factored) matmul — decode path
# ---------------------------------------------------------------------------

def _sl_matmul_sparse(x, B, A, v, rows, cols, scale, chunk: int = 1 << 20):
    """y = scale·(x@B)@A + sparse term, without densifying W. Reads only
    O((d_in+d_out)·r + nnz) parameter bytes — decode is memory-bound, so the
    compression ratio becomes decode bandwidth (DESIGN §3)."""
    lead = x.shape[:-1]
    d_in = x.shape[-1]
    d_out = A.shape[-1]
    # Accumulate in f32 end to end: the bf16 intermediate casts of the old
    # path ((x@B)@A each rounded to bf16, sparse contribs formed in bf16)
    # drifted several ulp from the densified path — enough to flip greedy
    # argmax in decode. One final rounding, like the dense path's matmul.
    xf = x.reshape(-1, d_in).astype(jnp.float32)
    y = ((xf @ B.astype(jnp.float32)) @ A.astype(jnp.float32)) * scale
    rows = rows.reshape(-1)
    cols = cols.reshape(-1)
    vf = v.reshape(-1).astype(jnp.float32)
    nnz = rows.shape[0]
    chunk = min(chunk, nnz)
    n_chunks = max(1, (nnz + chunk - 1) // chunk)
    pad = n_chunks * chunk - nnz
    rows_p = jnp.pad(rows, (0, pad)).reshape(n_chunks, chunk)
    cols_p = jnp.pad(cols, (0, pad)).reshape(n_chunks, chunk)
    v_p = jnp.pad(vf, (0, pad)).reshape(n_chunks, chunk)  # padded v == 0

    def body(acc, args):
        r, c, vv = args
        contrib = xf[:, r] * vv[None, :]                        # (N, chunk) f32
        upd = jnp.zeros((d_out, acc.shape[0]), dtype=jnp.float32)
        upd = upd.at[c].add(contrib.T)                          # segsum by col
        return acc + upd.T, None

    if n_chunks == 1:
        y, _ = body(y, (rows_p[0], cols_p[0], v_p[0]))
    else:
        y, _ = jax.lax.scan(body, y, (rows_p, cols_p, v_p))
    return y.astype(x.dtype).reshape(*lead, d_out)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def _rb_rows(cols):
    d_in = cols.shape[0]
    return jnp.broadcast_to(jnp.arange(d_in, dtype=jnp.int32)[:, None], cols.shape)


def sl_matmul(x, params, consts, scale: float, exec_mode: str = "dense"):
    """Apply one SLTrain linear. params={B,A,v};
    consts={cols[,rows][,rows_t,cols_t,perm][,qv_t,rows_q,cols_q,qscale]}."""
    rb = "rows" not in consts
    if exec_mode == "quant":
        if "qv_t" not in consts:
            raise ValueError(
                "exec_mode='quant' needs quantized consts {qv_t, rows_q, "
                "cols_q, qscale} — run repro.quant.calibrate on the trained "
                "checkpoint and serve the exported artifact")
        from repro.kernels import ops
        return ops.sl_quant_decode(x, params["B"], params["A"],
                                   consts["qv_t"], consts["rows_q"],
                                   consts["cols_q"], consts["qscale"], scale)
    if exec_mode == "fused":
        if "perm" not in consts:
            raise ValueError(
                "exec_mode='fused' needs tile consts {rows_t, cols_t, perm} "
                "— init the layer with exec_mode='fused' "
                "(core.sltrain.init_params / Builder.linear)")
        from repro.kernels import ops
        return ops.sl_linear(x, params["B"], params["A"], params["v"],
                             consts["rows_t"], consts["cols_t"],
                             consts["perm"], scale)
    if exec_mode == "sparse":
        rows = _rb_rows(consts["cols"]) if rb else consts["rows"]
        return _sl_matmul_sparse(x, params["B"], params["A"], params["v"],
                                 rows, consts["cols"], scale)
    if rb:
        return _sl_matmul_rb(x, params["B"], params["A"], params["v"],
                             consts["cols"], scale)
    return _sl_matmul_coo(x, params["B"], params["A"], params["v"],
                          (consts["rows"], consts["cols"]), scale)


def materialize(params, consts, scale: float):
    """Densified W (for export / tests)."""
    if "rows" not in consts:
        return densify_rb(params["B"], params["A"], params["v"],
                          consts["cols"], scale)
    return densify_coo(params["B"], params["A"], params["v"],
                       consts["rows"], consts["cols"], scale)


def param_count(d_in: int, d_out: int, rank: int, delta: float,
                support_kind: str = "row_balanced") -> Tuple[int, int]:
    """(trainable, index) parameter counts — paper's (d+p)r + δdp."""
    nnz = support_lib.nnz_for(d_in, d_out, delta, support_kind)
    return (d_in + d_out) * rank + nnz, nnz
