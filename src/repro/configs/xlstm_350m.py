"""xlstm-350m — 24L d_model=1024 4H, mLSTM + sLSTM blocks at 7:1,
vocab=50304 (d_ff=0: blocks define their own projections).
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig, ParamConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="xlstm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    max_seq_len=4096,
    tie_embeddings=False,
    xlstm_m_per_s=7,
    ssm=SSMConfig(chunk=128),
    param=ParamConfig(mode="sltrain", rank=256, delta=0.03, alpha=8.0),
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="xlstm",
    n_layers=4,          # 2 supers of (1 mLSTM + 1 sLSTM)
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    vocab_pad_multiple=16,
    max_seq_len=128,
    tie_embeddings=False,
    xlstm_m_per_s=1,
    ssm=SSMConfig(chunk=32),
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=8.0),
)
