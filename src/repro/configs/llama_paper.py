"""The paper's own LLaMA pretraining configs (60M–7B; Table 2 lineage from
GaLore/ReLoRA) with the paper's exact SLTrain hyperparameters (§5.1):
fixed support δ=0.03 (0.05 for 7B), LoRA-init factors, α per model size."""
from repro.configs.base import ModelConfig, ParamConfig


def _mk(name, n_layers, d_model, d_ff, n_heads, rank, alpha, delta=0.03,
        lr_note=0.003):
    return ModelConfig(
        name=name,
        family="llama",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_heads,
        d_ff=d_ff,
        vocab_size=32000,
        vocab_pad_multiple=256,
        max_seq_len=256,
        tie_embeddings=False,
        param=ParamConfig(mode="sltrain", rank=rank, delta=delta, alpha=alpha),
    )


LLAMA_60M = _mk("llama-60m", 8, 512, 1376, 8, rank=128, alpha=32.0)
LLAMA_130M = _mk("llama-130m", 12, 768, 2048, 12, rank=256, alpha=16.0)
LLAMA_350M = _mk("llama-350m", 24, 1024, 2736, 16, rank=256, alpha=16.0)
LLAMA_1B = _mk("llama-1b", 24, 2048, 5461, 32, rank=512, alpha=8.0)
LLAMA_7B = _mk("llama-7b", 32, 4096, 11008, 32, rank=1024, alpha=8.0, delta=0.05)

BY_SIZE = {"60m": LLAMA_60M, "130m": LLAMA_130M, "350m": LLAMA_350M,
           "1b": LLAMA_1B, "7b": LLAMA_7B}
