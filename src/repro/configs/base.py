"""Config dataclasses for the repro framework.

Everything is a plain frozen dataclass so configs hash (usable as jit static
args) and serialize into checkpoints for config-drift detection.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Parameterization (the paper's contribution lives here)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamConfig:
    """How linear-layer weights are parameterized.

    mode:
      dense   — full-rank W                      (paper baseline "Full-Rank")
      lowrank — W = (alpha/r) B A                (paper baseline "Low-Rank" [24])
      sltrain — W = (alpha/r) B A  ⊕_I  V        (the paper's method)
      relora  — W = W0 + (alpha/r) B A, periodic merge (paper baseline [32])
    """
    mode: str = "dense"
    rank: int = 128
    delta: float = 0.03
    alpha: float = 32.0
    # "row_balanced" gives each row exactly round(delta*d_out) entries (better
    # tile balance + Prop.1 coverage); "iid" matches the paper's sampling.
    support_kind: str = "row_balanced"
    # Execution mode matrix for the sltrain factors (DESIGN §3). Trainable
    # params are identical across modes; only execution (and, for "fused",
    # extra int32 index consts) differs:
    #   "dense"  — densify W on the fly, one MXU matmul; the XLA baseline
    #              for training. W is a transient, never a residual.
    #   "sparse" — factored gather path: reads only (d+p)r + nnz parameter
    #              bytes; the decode/serve path (compression ratio becomes
    #              decode bandwidth).
    #   "fused"  — Pallas custom-VJP path for training: sl_matmul densifies
    #              per 128×128 tile in VMEM (forward + dx), sddmm gathers
    #              dV without the G transient; dense W never touches HBM.
    #              Init emits tile consts (core/sltrain.py).
    #   "quant"  — SERVE-ONLY post-training int8 path: sparse values are
    #              int8 tile-CSR codes (repro.quant) dequantized in-kernel
    #              against per-channel scales; B/A stay bf16 with the quant
    #              error SVD-folded in. Requires calibrated consts
    #              {qv_t, rows_q, cols_q, qscale} from a quant artifact
    #              (python -m repro.quant.calibrate); make_train_step
    #              rejects it.
    exec_mode: str = "dense"
    # ReLoRA restart period (steps), used only in mode == "relora".
    relora_period: int = 2000

    # NOTE: there is deliberately no global ``scale`` here. The effective
    # rank is per matrix — Builder.linear caps it at min(d_in, d_out)//2
    # (MoE expert/gate matrices are much smaller than attention ones) — so
    # the LoRA scale is alpha / B.shape[-1] at the use site; a global
    # alpha/rank silently disagrees wherever the cap binds.


# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 2
    n_shared_experts: int = 0     # deepseek-style always-on experts
    d_ff_expert: int = 0          # per-expert hidden dim
    first_k_dense: int = 0        # first k layers use a dense FFN
    d_ff_dense: int = 0           # hidden dim of those dense layers
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64           # N (per-head state size)
    conv_width: int = 4
    n_ssm_heads: int = 0          # mamba2 heads (d_inner / head_dim)
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128              # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str = "llama"
    # family: llama | moe | gemma2 | mamba_hybrid | xlstm | whisper | vlm
    family: str = "llama"
    n_layers: int = 8
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 1376
    vocab_size: int = 32000
    vocab_pad_multiple: int = 256  # pad vocab so TP divides (DESIGN §4)
    max_seq_len: int = 4096
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    qkv_bias: bool = False        # qwen2.5
    tie_embeddings: bool = True
    # gemma2
    sliding_window: int = 4096
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    use_post_norms: bool = False  # gemma2 post-attn/post-ffn norms
    query_pre_attn_scalar: float = 0.0  # gemma2 uses d_model/n_heads
    attn_pattern: Tuple[str, ...] = ()  # e.g. ("local","global"); empty = all global
    # QK-norm (qwen3)
    qk_norm: bool = False
    # Attention read path over the paged KV cache (serve/kv.py):
    #   "gather" — materialize the gathered (n_slots, view_len) per-slot
    #              view, dense attention over it (the PR-2 baseline; kept
    #              selectable as the kernel's always-available oracle)
    #   "paged"  — kernels/paged_attention.py streams K/V blocks through
    #              VMEM with online softmax; the view never exists and
    #              decode HBM K/V traffic tracks live tokens. Per-slot
    #              chunked prefill (shared-prefix suffixes) routes through
    #              the sibling paged_prefill kernel the same way.
    # Default is "paged" since the kernel/model/engine parity gates baked
    # in CI (PR 5); a non-paged (contiguous-cache) engine silently falls
    # back to "gather" — the kernel needs block pools. Train and the
    # contiguous cache ignore this field.
    attn_kernel: str = "paged"
    moe: MoEConfig = field(default_factory=MoEConfig)
    # MoE routing groups, aligned with the batch sharding (pod*data size at
    # scale, 1 on a single device). Group-local dispatch, DESIGN §4.
    moe_groups: int = 1
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # mamba_hybrid (zamba2): how many mamba blocks between shared attn blocks
    hybrid_attn_every: int = 6
    # xlstm: ratio of mLSTM to sLSTM blocks per super-block
    xlstm_m_per_s: int = 7
    # whisper / vlm stubs
    encoder_layers: int = 0
    encoder_seq: int = 1500       # whisper stub frame count
    n_patches: int = 256          # paligemma stub patch count
    frontend_dim: int = 0         # stub embedding dim (0 -> d_model)
    # parameterization of linear layers (the paper's technique)
    param: ParamConfig = field(default_factory=ParamConfig)
    dtype: str = "bfloat16"
    # Sequence parallelism (§Perf iteration 2): constrain the residual
    # stream inside the layer scan to shard its sequence dim over "model",
    # so saved activations shrink by the TP degree. XLA inserts the
    # all-gather at attention / reduce-scatter after (standard SP).
    seq_shard_activations: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim > 0 else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    def hash(self) -> str:
        return hashlib.sha256(
            json.dumps(dataclasses.asdict(self), sort_keys=True, default=str).encode()
        ).hexdigest()[:16]


# ---------------------------------------------------------------------------
# Training / runtime
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"           # adamw | adam8bit | galore_adamw
    lr: float = 3e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # GaLore
    galore_rank: int = 128
    galore_update_proj_gap: int = 200
    galore_scale: float = 0.25
    # 8-bit Adam
    q_block: int = 256


@dataclass(frozen=True)
class ShardingConfig:
    """Named-axis sharding policy + train-step execution knobs (DESIGN §4).

    ``update_mode`` picks the optimizer-update schedule (ISSUE 4):
      global    — one ``optimizer.update`` over the full gradient tree
                  (train/step.py). Peak grad+opt-transient HBM is
                  O(P_trainable).
      per_layer — repro.train.perlayer: forward saves per-layer boundary
                  activations, then a reverse sweep vjp's one layer at a
                  time and applies that layer's update in-sweep, so
                  co-resident grads + f32 optimizer transients are
                  O(P_layer) (the paper's §5.1/Appendix-F "per-layer
                  updates"; with adam8bit this is the 7B 73% path).
    The mode composes orthogonally with ``ParamConfig.exec_mode``
    (dense | sparse | fused): exec_mode picks how each SLTrain linear
    RUNS, update_mode picks how its gradients are CONSUMED. Under
    per_layer + exec_mode="fused", sliced adam8bit updates dispatch to the
    fused Pallas kernel (kernels/adam8bit.py) instead of the XLA
    reference. per_layer requires an lm-family model (the PerLayerApi in
    models/registry.py); grad_accum > 1 runs the in-sweep microbatch
    accumulator (per-layer grads accumulate across microbatches inside
    the reverse sweep — the full gradient tree is never materialized).

    ``fsdp`` additionally shards parameters and optimizer state over
    ``fsdp_axis`` (the data axis): the spec engine appends the fsdp axes
    to the first matrix dim they divide, composing with the TP rules
    without ever using a mesh axis twice (dist/sharding.py); grads are
    pinned back to the sharded layout before the update (reduce-scatter
    instead of all-reduce + slice). Support matrix
    (update_mode × exec_mode × fsdp — all 12 combinations lower):

      update_mode  exec_mode      fsdp=False          fsdp=True
      global       dense/sparse   baseline            params/opt 1/N_data
      global       fused          Pallas tile kernels tile consts shard the
                                  (replicated consts) d_out tile axis over
                                                      model; params/opt
                                                      shard over data
      per_layer    dense/sparse   O(P_layer) grads    sliced grads pinned
                                                      to the layout the
                                                      stacked leaf shards
      per_layer    fused          fused adam8bit      both compose: the
                                  slices              sweep slices the
                                                      layer dim, fsdp
                                                      shards matrix dims

    grad_accum composes with every row (global: microbatch scan;
    per_layer: in-sweep accumulator).
    """
    batch_axes: Tuple[str, ...] = ("pod", "data")
    model_axis: str = "model"
    fsdp: bool = False            # shard params/opt over the data axis too
    fsdp_axis: str = "data"
    remat: str = "none"           # none | full | dots_saveable
    grad_accum: int = 1
    update_mode: str = "global"   # global | per_layer (see docstring)
    # int8 compression of the cross-pod gradient all-reduce (DESIGN §4)
    pod_grad_compression: bool = False
    # shard KV cache sequence dim over the model axis for long-context decode
    seq_shard_decode: bool = False


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    optim: OptimizerConfig = field(default_factory=OptimizerConfig)
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    seed: int = 42
    global_batch: int = 8
    seq_len: int = 256
    steps: int = 50
    log_every: int = 10
    ckpt_every: int = 1000
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = True
    keep_ckpts: int = 3


# ---------------------------------------------------------------------------
# Shape cells (assigned input shapes; system prompt)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode" | "long_decode"


SHAPE_CELLS = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "long_decode"),
)
