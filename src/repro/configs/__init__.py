from repro.configs.base import (ModelConfig, MoEConfig, OptimizerConfig,
                                ParamConfig, ShapeCell, ShardingConfig,
                                SHAPE_CELLS, SSMConfig, TrainConfig)

__all__ = ["ModelConfig", "MoEConfig", "OptimizerConfig", "ParamConfig",
           "ShapeCell", "ShardingConfig", "SHAPE_CELLS", "SSMConfig",
           "TrainConfig"]
