"""yi-34b — 60L d_model=7168 56H (GQA kv=8, head_dim=128) d_ff=20480
vocab=64000, llama-arch GQA. [arXiv:2403.04652; hf]"""
from repro.configs.base import ModelConfig, ParamConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="llama",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    max_seq_len=4096,
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    param=ParamConfig(mode="sltrain", rank=1792, delta=0.03, alpha=8.0),
)

SMOKE = ModelConfig(
    name="yi-smoke",
    family="llama",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    vocab_pad_multiple=16,
    max_seq_len=128,
    tie_embeddings=False,
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=8.0),
)
