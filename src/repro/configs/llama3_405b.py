"""llama3-405b — 126L d_model=16384 128H (GQA kv=8, head_dim=128)
d_ff=53248 vocab=128256. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ModelConfig, ParamConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="llama",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    max_seq_len=8192,
    rope_theta=500_000.0,
    tie_embeddings=False,
    param=ParamConfig(mode="sltrain", rank=4096, delta=0.03, alpha=8.0),
)

SMOKE = ModelConfig(
    name="llama3-smoke",
    family="llama",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    vocab_pad_multiple=16,
    max_seq_len=128,
    tie_embeddings=False,
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=8.0),
)
