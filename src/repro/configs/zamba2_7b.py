"""zamba2-7b — 81L d_model=3584, Mamba2 blocks + ONE weight-shared attention
block (32H MHA) invoked every 6 layers with per-invocation low-rank
adapters; d_ff=14336, vocab=32000, ssm_state=64. [arXiv:2411.15242;
unverified]"""
from repro.configs.base import ModelConfig, ParamConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="mamba_hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    max_seq_len=4096,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=64, conv_width=4, head_dim=64, expand=2, chunk=128),
    hybrid_attn_every=6,
    param=ParamConfig(mode="sltrain", rank=896, delta=0.03, alpha=8.0),
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="mamba_hybrid",
    n_layers=5,          # 2 supers of 2 + 1 tail
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    vocab_pad_multiple=16,
    max_seq_len=128,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=16, conv_width=4, head_dim=16, expand=2, chunk=32),
    hybrid_attn_every=2,
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=8.0),
)
