"""whisper-large-v3 — enc-dec, 32+32L d_model=1280 20H (MHA kv=20,
head_dim=64) d_ff=5120 vocab=51866; conv frontend is a STUB providing
precomputed frame embeddings (1500 frames). Decoder position table is
extended to the assigned 32k shapes (DESIGN §5). [arXiv:2212.04356;
unverified]"""
from repro.configs.base import ModelConfig, ParamConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="whisper",
    n_layers=32,
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    max_seq_len=32768,
    qkv_bias=True,
    tie_embeddings=True,
    param=ParamConfig(mode="sltrain", rank=320, delta=0.03, alpha=8.0),
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="whisper",
    n_layers=2,
    encoder_layers=2,
    encoder_seq=16,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    vocab_pad_multiple=16,
    max_seq_len=128,
    qkv_bias=True,
    tie_embeddings=True,
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=8.0),
)
