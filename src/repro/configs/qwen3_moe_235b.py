"""qwen3-moe-235b-a22b — 94L d_model=4096 64H (GQA kv=4, head_dim=128)
d_ff(expert)=1536, vocab=151936, MoE 128 experts top-8, QK-norm.
[hf:Qwen/Qwen3-30B-A3B family scaling; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, ParamConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    max_seq_len=32768,
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
    param=ParamConfig(mode="sltrain", rank=1024, delta=0.03, alpha=8.0),
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=0,
    vocab_size=512,
    vocab_pad_multiple=16,
    max_seq_len=128,
    qk_norm=True,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32),
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=8.0),
)
