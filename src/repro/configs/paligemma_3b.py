"""paligemma-3b — gemma backbone: 18L d_model=2048 8H (MQA kv=1,
head_dim=256) d_ff=16384 vocab=257216; SigLIP frontend is a STUB providing
precomputed patch embeddings (DESIGN §5). [arXiv:2407.07726; hf]"""
from repro.configs.base import ModelConfig, ParamConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    max_seq_len=8192,
    tie_embeddings=True,
    n_patches=256,
    param=ParamConfig(mode="sltrain", rank=512, delta=0.03, alpha=8.0),
)

SMOKE = ModelConfig(
    name="paligemma-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    vocab_pad_multiple=16,
    max_seq_len=128,
    tie_embeddings=True,
    n_patches=8,
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=8.0),
)
