"""qwen2.5-32b — 64L d_model=5120 40H (GQA kv=8, head_dim=128) d_ff=27648
vocab=152064, QKV bias. [hf:Qwen/Qwen2.5 family; hf]"""
from repro.configs.base import ModelConfig, ParamConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="llama",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    max_seq_len=32768,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=False,
    param=ParamConfig(mode="sltrain", rank=1280, delta=0.03, alpha=8.0),
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke",
    family="llama",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    vocab_pad_multiple=16,
    max_seq_len=128,
    qkv_bias=True,
    tie_embeddings=False,
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=8.0),
)
