"""deepseek-moe-16b — 28L d_model=2048 16H (MHA kv=16) d_ff(expert)=1408,
vocab=102400, 64 routed experts top-6 + 2 shared, first layer dense
(fine-grained expert segmentation). [arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, ParamConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=102400,
    max_seq_len=4096,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408,
                  first_k_dense=1, d_ff_dense=10944),
    param=ParamConfig(mode="sltrain", rank=512, delta=0.03, alpha=8.0),
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=0,
    vocab_size=512,
    vocab_pad_multiple=16,
    max_seq_len=128,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared_experts=1, d_ff_expert=32,
                  first_k_dense=1, d_ff_dense=128),
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=8.0),
)
