"""gemma2-2b — 26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216
vocab=256000; local+global alternating attention, attn/final logit softcaps,
pre+post RMSNorms with (1+w) scaling. [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig, ParamConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="gemma2",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    max_seq_len=8192,
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norms=True,
    query_pre_attn_scalar=256.0,
    attn_pattern=("local", "global"),
    tie_embeddings=True,
    param=ParamConfig(mode="sltrain", rank=576, delta=0.03, alpha=8.0),
)

SMOKE = ModelConfig(
    name="gemma2-smoke",
    family="gemma2",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    vocab_pad_multiple=16,
    max_seq_len=128,
    sliding_window=32,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norms=True,
    attn_pattern=("local", "global"),
    tie_embeddings=True,
    param=ParamConfig(mode="sltrain", rank=8, delta=0.05, alpha=8.0),
)
