"""Paper LLaMA 7b config (see llama_paper.py)."""
from repro.configs.llama_paper import BY_SIZE, LLAMA_7B as CONFIG  # noqa: F401
import dataclasses
from repro.configs.base import ParamConfig

SMOKE = dataclasses.replace(
    CONFIG, name=CONFIG.name + "-smoke", n_layers=2, d_model=64, d_ff=160,
    n_heads=4, n_kv_heads=4, vocab_size=512, vocab_pad_multiple=16,
    max_seq_len=128,
    param=dataclasses.replace(CONFIG.param, rank=8, delta=0.05))
