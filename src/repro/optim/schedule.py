"""LR schedules (pure functions of the int step)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig


def warmup_cosine(oc: OptimizerConfig):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = jnp.minimum(1.0, (step + 1) / max(1, oc.warmup_steps))
        t = jnp.clip((step - oc.warmup_steps)
                     / max(1, oc.total_steps - oc.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(np.pi * t))
        frac = oc.min_lr_ratio + (1.0 - oc.min_lr_ratio) * cos
        return oc.lr * warm * frac
    return lr
