"""Pure-JAX pytree optimizers: AdamW, blockwise-8-bit AdamW, GaLore-AdamW.

Interface:
    opt = adamw(oc)
    state = opt.init(params)
    new_params, new_state, stats = opt.update(grads, state, params)

All optimizers share: global-norm gradient clipping, warmup-cosine schedule,
decoupled weight decay on >=2-D leaves. The optimizer never sees the fixed
SLTrain support (consts live outside the trainable tree), so its state
scales with the *trainable* parameter count — the paper's memory claim.

Per-layer API (ISSUE 4, ``repro.train.perlayer``): the one-step scalar math
is split out of ``update`` so a layer-wise backward sweep can apply one
layer's update while only that layer's gradients exist:

    ctx, stats = opt.prepare(state, global_grad_norm)   # step/lr/clip/bias
    new_p, new_ls = opt.update_slice(ctx, p, g, ls, full_ndim=...)
    state = opt.finish(state, ctx)                      # bump step counter

``ls`` is one param leaf's state (``leaf_state``/``with_leaf_state``
address it by tree path); ``stack_state`` reshapes it so a leading
layer-stack axis of size n can be sliced — returning None when it cannot
(adam8bit blocks that straddle layer boundaries, GaLore projected leaves),
in which case the sweep accumulates that leaf's full gradient and updates
it once at the end. The GLOBAL ``update`` of every optimizer is routed
through the same ``prepare``/``update_slice`` path, so per-layer and global
modes agree leaf-for-leaf by construction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.optim import quant
from repro.optim.schedule import warmup_cosine


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable   # (grads, state, params) -> (new_params, new_state, stats)
    # --- per-layer slice API (repro.train.perlayer); None = unsupported ---
    prepare: Optional[Callable] = None        # (state, gnorm) -> (ctx, stats)
    update_slice: Optional[Callable] = None   # (ctx, p, g, ls, full_ndim=None)
    update_slice_fused: Optional[Callable] = None  # Pallas-kernel dispatch
    leaf_state: Optional[Callable] = None     # (state, path) -> ls
    with_leaf_state: Optional[Callable] = None  # (state, path, ls) -> state
    stack_state: Optional[Callable] = None    # (ls, p_leaf, n) -> ls | None
    unstack_state: Optional[Callable] = None  # (ls_stacked, p_leaf, n) -> ls
    finish: Optional[Callable] = None         # (state, ctx) -> state


def _global_norm(grads):
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


# -- nested-dict path addressing (all param/state trees here are dicts) -----

def _tree_get(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def _tree_set(tree, path, val):
    if not path:
        return val
    out = dict(tree)
    out[path[0]] = _tree_set(tree[path[0]], path[1:], val)
    return out


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(oc: OptimizerConfig) -> Optimizer:
    lr_fn = warmup_cosine(oc)
    b1, b2 = oc.beta1, oc.beta2

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def prepare(state, gnorm):
        step = state["step"] + 1
        scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9))
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = lr_fn(step)
        ctx = {"step": step, "scale": scale, "bc1": bc1, "bc2": bc2, "lr": lr}
        return ctx, {"grad_norm": gnorm, "lr": lr}

    def update_slice(ctx, p, g, ls, full_ndim=None):
        g = g.astype(jnp.float32) * ctx["scale"]
        m = b1 * ls["mu"] + (1 - b1) * g
        v = b2 * ls["nu"] + (1 - b2) * g * g
        u = (m / ctx["bc1"]) / (jnp.sqrt(v / ctx["bc2"]) + oc.eps)
        nd = p.ndim if full_ndim is None else full_ndim
        if oc.weight_decay > 0 and nd >= 2:
            u = u + oc.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - ctx["lr"] * u).astype(p.dtype)
        return new_p, {"mu": m, "nu": v}

    def update(grads, state, params):
        ctx, stats = prepare(state, _global_norm(grads))
        paired = jax.tree.map(
            lambda p, g, m, v: update_slice(ctx, p, g, {"mu": m, "nu": v}),
            params, grads, state["mu"], state["nu"])
        is_pair = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda t: t[0], paired, is_leaf=is_pair)
        mu = jax.tree.map(lambda t: t[1]["mu"], paired, is_leaf=is_pair)
        nu = jax.tree.map(lambda t: t[1]["nu"], paired, is_leaf=is_pair)
        return new_params, {"mu": mu, "nu": nu, "step": ctx["step"]}, stats

    def leaf_state(state, path):
        return {"mu": _tree_get(state["mu"], path),
                "nu": _tree_get(state["nu"], path)}

    def with_leaf_state(state, path, ls):
        out = dict(state)
        out["mu"] = _tree_set(state["mu"], path, ls["mu"])
        out["nu"] = _tree_set(state["nu"], path, ls["nu"])
        return out

    def stack_state(ls, p_leaf, n):
        # moments mirror the param leaf, whose leading axis IS the stack
        return ls

    def unstack_state(ls, p_leaf, n):
        return ls

    def finish(state, ctx):
        return {**state, "step": ctx["step"]}

    return Optimizer(init, update, prepare=prepare, update_slice=update_slice,
                     leaf_state=leaf_state, with_leaf_state=with_leaf_state,
                     stack_state=stack_state, unstack_state=unstack_state,
                     finish=finish)


# ---------------------------------------------------------------------------
# Blockwise 8-bit AdamW (paper §5.1 "8-bit SLTrain")
# ---------------------------------------------------------------------------

def adam8bit(oc: OptimizerConfig) -> Optimizer:
    lr_fn = warmup_cosine(oc)
    b1, b2 = oc.beta1, oc.beta2
    block = oc.q_block

    def _q(x, signed):
        return quant.quantize_blockwise(x, block, signed)

    def init(params):
        def qz(p):
            z = jnp.zeros(p.shape, jnp.float32)
            cq, sq, n = _q(z, True)
            return {"codes": cq, "scales": sq}
        def qz_u(p):
            z = jnp.zeros(p.shape, jnp.float32)
            cq, sq, n = _q(z, False)
            return {"codes": cq, "scales": sq}
        return {"mu": jax.tree.map(qz, params),
                "nu": jax.tree.map(qz_u, params),
                "step": jnp.zeros((), jnp.int32)}

    def prepare(state, gnorm):
        step = state["step"] + 1
        scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9))
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = lr_fn(step)
        ctx = {"step": step, "scale": scale, "bc1": bc1, "bc2": bc2, "lr": lr}
        return ctx, {"grad_norm": gnorm, "lr": lr}

    def update_slice(ctx, p, g, ls, full_ndim=None):
        """XLA reference path: dequantize -> f32 Adam -> requantize. Blocks
        are independent, so applying this to a layer slice whose flat size
        is a whole number of q-blocks is bitwise identical to the global
        update of those blocks."""
        g = g.astype(jnp.float32) * ctx["scale"]
        n = p.size
        m = quant.dequantize_blockwise(ls["mu"]["codes"], ls["mu"]["scales"],
                                       n, p.shape, True)
        v = quant.dequantize_blockwise(ls["nu"]["codes"], ls["nu"]["scales"],
                                       n, p.shape, False)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / ctx["bc1"]) / (jnp.sqrt(v / ctx["bc2"]) + oc.eps)
        nd = p.ndim if full_ndim is None else full_ndim
        if oc.weight_decay > 0 and nd >= 2:
            u = u + oc.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - ctx["lr"] * u).astype(p.dtype)
        mc, ms, _ = _q(m, True)
        vc, vs, _ = _q(v, False)
        return new_p, {"mu": {"codes": mc, "scales": ms},
                       "nu": {"codes": vc, "scales": vs}}

    def update_slice_fused(ctx, p, g, ls, full_ndim=None):
        """Pallas-kernel dispatch: one fused pass, f32 moments only in VMEM.
        Tracks the XLA path to codes-exact / params-ulp (tests/test_kernels
        tail-trajectory parity)."""
        from repro.kernels import ops
        g = g.astype(jnp.float32) * ctx["scale"]
        nd = p.ndim if full_ndim is None else full_ndim
        wd = oc.weight_decay if (oc.weight_decay > 0 and nd >= 2) else 0.0
        new_p, mc, ms, vc, vs = ops.adam8bit_update(
            p, g, ls["mu"]["codes"], ls["mu"]["scales"],
            ls["nu"]["codes"], ls["nu"]["scales"],
            lr=ctx["lr"], b1=b1, b2=b2, bc1=ctx["bc1"], bc2=ctx["bc2"],
            eps=oc.eps, wd=wd, q=block)
        return new_p, {"mu": {"codes": mc, "scales": ms},
                       "nu": {"codes": vc, "scales": vs}}

    def update(grads, state, params):
        ctx, stats = prepare(state, _global_norm(grads))
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["mu"])
        flat_v = treedef.flatten_up_to(state["nu"])
        out = [update_slice(ctx, p, g, {"mu": m, "nu": v})
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1]["mu"] for o in out])
        nu = treedef.unflatten([o[1]["nu"] for o in out])
        return new_params, {"mu": mu, "nu": nu, "step": ctx["step"]}, stats

    def leaf_state(state, path):
        return {"mu": _tree_get(state["mu"], path),
                "nu": _tree_get(state["nu"], path)}

    def with_leaf_state(state, path, ls):
        out = dict(state)
        out["mu"] = _tree_set(state["mu"], path, ls["mu"])
        out["nu"] = _tree_set(state["nu"], path, ls["nu"])
        return out

    def stack_state(ls, p_leaf, n):
        """Reshape codes/scales so axis 0 indexes the n layer slices.
        Possible exactly when each slice is a whole number of q-blocks —
        otherwise blocks straddle layer boundaries and the leaf must take
        the deferred full-gradient path (returns None)."""
        if n <= 0 or p_leaf.size % n:
            return None
        per = p_leaf.size // n
        if per % block:
            return None
        bpl = per // block

        def go(moment):
            return {"codes": moment["codes"].reshape(n, bpl, block),
                    "scales": moment["scales"].reshape(n, bpl)}
        return {"mu": go(ls["mu"]), "nu": go(ls["nu"])}

    def unstack_state(ls, p_leaf, n):
        def go(moment):
            return {"codes": moment["codes"].reshape(-1, block),
                    "scales": moment["scales"].reshape(-1)}
        return {"mu": go(ls["mu"]), "nu": go(ls["nu"])}

    def finish(state, ctx):
        return {**state, "step": ctx["step"]}

    return Optimizer(init, update, prepare=prepare, update_slice=update_slice,
                     update_slice_fused=update_slice_fused,
                     leaf_state=leaf_state, with_leaf_state=with_leaf_state,
                     stack_state=stack_state, unstack_state=unstack_state,
                     finish=finish)


# ---------------------------------------------------------------------------
# GaLore-AdamW (paper baseline [59]): low-rank gradient projection
# ---------------------------------------------------------------------------

def galore_adamw(oc: OptimizerConfig, project_fn: Callable | None = None
                 ) -> Optimizer:
    """project_fn(path, leaf) -> bool: which leaves get projected moments.
    Default: 2-D leaves with both dims > galore_rank (linear weights)."""
    lr_fn = warmup_cosine(oc)
    r = oc.galore_rank
    b1, b2 = oc.beta1, oc.beta2

    def is_proj(path, p):
        if project_fn is not None:
            return project_fn(path, p)
        return p.ndim == 2 and min(p.shape) > r and "embed" not in str(path)

    def init(params):
        def st(path, p):
            if is_proj(path, p):
                d, q = p.shape
                if d <= q:
                    return {"P": jnp.zeros((d, r), jnp.float32),
                            "mu": jnp.zeros((r, q), jnp.float32),
                            "nu": jnp.zeros((r, q), jnp.float32)}
                return {"P": jnp.zeros((q, r), jnp.float32),
                        "mu": jnp.zeros((d, r), jnp.float32),
                        "nu": jnp.zeros((d, r), jnp.float32)}
            return {"mu": jnp.zeros(p.shape, jnp.float32),
                    "nu": jnp.zeros(p.shape, jnp.float32)}
        return {"leaves": jax.tree_util.tree_map_with_path(st, params),
                "step": jnp.zeros((), jnp.int32)}

    def prepare(state, gnorm):
        step = state["step"] + 1
        scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9))
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = lr_fn(step)
        refresh = (step - 1) % oc.galore_update_proj_gap == 0
        ctx = {"step": step, "scale": scale, "bc1": bc1, "bc2": bc2,
               "lr": lr, "refresh": refresh}
        return ctx, {"grad_norm": gnorm, "lr": lr}

    def update_slice(ctx, p, g, ls, full_ndim=None):
        g = g.astype(jnp.float32) * ctx["scale"]
        nd = p.ndim if full_ndim is None else full_ndim
        if "P" not in ls:
            m = b1 * ls["mu"] + (1 - b1) * g
            v = b2 * ls["nu"] + (1 - b2) * g * g
            u = (m / ctx["bc1"]) / (jnp.sqrt(v / ctx["bc2"]) + oc.eps)
            if oc.weight_decay > 0 and nd >= 2:
                u = u + oc.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - ctx["lr"] * u).astype(p.dtype), \
                {"mu": m, "nu": v}
        d, q = p.shape
        left = d <= q

        def new_P(_):
            # top-r singular vectors of the current gradient
            if left:
                u_, _, _ = jnp.linalg.svd(g @ g.T)   # (d,d)
                return u_[:, :r]
            _, _, vt = jnp.linalg.svd(g.T @ g)       # (q,q)
            return vt[:r].T
        P = jax.lax.cond(ctx["refresh"], new_P, lambda _: ls["P"], None)
        R = P.T @ g if left else g @ P               # projected gradient
        m = b1 * ls["mu"] + (1 - b1) * R
        v = b2 * ls["nu"] + (1 - b2) * R * R
        u_low = (m / ctx["bc1"]) / (jnp.sqrt(v / ctx["bc2"]) + oc.eps)
        u = (P @ u_low if left else u_low @ P.T) * oc.galore_scale
        if oc.weight_decay > 0:
            u = u + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - ctx["lr"] * u).astype(p.dtype), \
            {"P": P, "mu": m, "nu": v}

    def update(grads, state, params):
        ctx, stats = prepare(state, _global_norm(grads))
        paired = jax.tree_util.tree_map_with_path(
            lambda path, p, g, st: update_slice(ctx, p, g, st),
            params, grads, state["leaves"],
            is_leaf=lambda x: isinstance(x, jnp.ndarray))
        # unzip (params, state) tuples
        new_params = jax.tree.map(lambda t: t[0], paired,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_leaves = jax.tree.map(lambda t: t[1], paired,
                                  is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"leaves": new_leaves, "step": ctx["step"]}, stats

    def leaf_state(state, path):
        return _tree_get(state["leaves"], path)

    def with_leaf_state(state, path, ls):
        return {**state, "leaves": _tree_set(state["leaves"], path, ls)}

    def stack_state(ls, p_leaf, n):
        # projected state shares one P/moment pair across the whole leaf —
        # it cannot be sliced layer-wise (and stacked >=3-D leaves are
        # never projected, see is_proj)
        if "P" in ls:
            return None
        return ls

    def unstack_state(ls, p_leaf, n):
        return ls

    def finish(state, ctx):
        return {**state, "step": ctx["step"]}

    return Optimizer(init, update, prepare=prepare, update_slice=update_slice,
                     leaf_state=leaf_state, with_leaf_state=with_leaf_state,
                     stack_state=stack_state, unstack_state=unstack_state,
                     finish=finish)


def make(oc: OptimizerConfig) -> Optimizer:
    return {"adamw": adamw, "adam8bit": adam8bit,
            "galore_adamw": galore_adamw}[oc.name](oc)
