"""Pure-JAX pytree optimizers: AdamW, blockwise-8-bit AdamW, GaLore-AdamW.

Interface:
    opt = adamw(oc)
    state = opt.init(params)
    new_params, new_state, stats = opt.update(grads, state, params)

All optimizers share: global-norm gradient clipping, warmup-cosine schedule,
decoupled weight decay on >=2-D leaves. The optimizer never sees the fixed
SLTrain support (consts live outside the trainable tree), so its state
scales with the *trainable* parameter count — the paper's memory claim.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.optim import quant
from repro.optim.schedule import warmup_cosine


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable   # (grads, state, params) -> (new_params, new_state, stats)


def _clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def _wd_mask(p):
    return p.ndim >= 2


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(oc: OptimizerConfig) -> Optimizer:
    lr_fn = warmup_cosine(oc)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = _clip_by_global_norm(grads, oc.grad_clip)
        b1, b2 = oc.beta1, oc.beta2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = lr_fn(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
            if oc.weight_decay > 0 and _wd_mask(p):
                u = u + oc.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "step": step}, \
            {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Blockwise 8-bit AdamW (paper §5.1 "8-bit SLTrain")
# ---------------------------------------------------------------------------

def adam8bit(oc: OptimizerConfig) -> Optimizer:
    lr_fn = warmup_cosine(oc)
    block = oc.q_block

    def _q(x, signed):
        return quant.quantize_blockwise(x, block, signed)

    def init(params):
        def qz(p):
            z = jnp.zeros(p.shape, jnp.float32)
            cq, sq, n = _q(z, True)
            return {"codes": cq, "scales": sq}
        def qz_u(p):
            z = jnp.zeros(p.shape, jnp.float32)
            cq, sq, n = _q(z, False)
            return {"codes": cq, "scales": sq}
        return {"mu": jax.tree.map(qz, params),
                "nu": jax.tree.map(qz_u, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = _clip_by_global_norm(grads, oc.grad_clip)
        b1, b2 = oc.beta1, oc.beta2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = lr_fn(step)

        def upd(p, g, mq, vq):
            n = p.size
            m = quant.dequantize_blockwise(mq["codes"], mq["scales"], n, p.shape, True)
            v = quant.dequantize_blockwise(vq["codes"], vq["scales"], n, p.shape, False)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
            if oc.weight_decay > 0 and _wd_mask(p):
                u = u + oc.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            mc, ms, _ = _q(m, True)
            vc, vs, _ = _q(v, False)
            return new_p, {"codes": mc, "scales": ms}, {"codes": vc, "scales": vs}

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["mu"])
        flat_v = treedef.flatten_up_to(state["nu"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1] for o in out])
        nu = treedef.unflatten([o[2] for o in out])
        return new_params, {"mu": mu, "nu": nu, "step": step}, \
            {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# GaLore-AdamW (paper baseline [59]): low-rank gradient projection
# ---------------------------------------------------------------------------

def galore_adamw(oc: OptimizerConfig, project_fn: Callable | None = None
                 ) -> Optimizer:
    """project_fn(path, leaf) -> bool: which leaves get projected moments.
    Default: 2-D leaves with both dims > galore_rank (linear weights)."""
    lr_fn = warmup_cosine(oc)
    r = oc.galore_rank

    def is_proj(path, p):
        if project_fn is not None:
            return project_fn(path, p)
        return p.ndim == 2 and min(p.shape) > r and "embed" not in str(path)

    def init(params):
        def st(path, p):
            if is_proj(path, p):
                d, q = p.shape
                if d <= q:
                    return {"P": jnp.zeros((d, r), jnp.float32),
                            "mu": jnp.zeros((r, q), jnp.float32),
                            "nu": jnp.zeros((r, q), jnp.float32)}
                return {"P": jnp.zeros((q, r), jnp.float32),
                        "mu": jnp.zeros((d, r), jnp.float32),
                        "nu": jnp.zeros((d, r), jnp.float32)}
            return {"mu": jnp.zeros(p.shape, jnp.float32),
                    "nu": jnp.zeros(p.shape, jnp.float32)}
        return {"leaves": jax.tree_util.tree_map_with_path(st, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = _clip_by_global_norm(grads, oc.grad_clip)
        b1, b2 = oc.beta1, oc.beta2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = lr_fn(step)
        refresh = (step - 1) % oc.galore_update_proj_gap == 0

        def upd(path, p, g, st):
            if "P" not in st:
                m = b1 * st["mu"] + (1 - b1) * g
                v = b2 * st["nu"] + (1 - b2) * g * g
                u = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
                if oc.weight_decay > 0 and _wd_mask(p):
                    u = u + oc.weight_decay * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - lr * u).astype(p.dtype), \
                    {"mu": m, "nu": v}
            d, q = p.shape
            left = d <= q

            def new_P(_):
                # top-r singular vectors of the current gradient
                if left:
                    u_, _, _ = jnp.linalg.svd(g @ g.T)   # (d,d)
                    return u_[:, :r]
                _, _, vt = jnp.linalg.svd(g.T @ g)       # (q,q)
                return vt[:r].T
            P = jax.lax.cond(refresh, new_P, lambda _: st["P"], None)
            R = P.T @ g if left else g @ P               # projected gradient
            m = b1 * st["mu"] + (1 - b1) * R
            v = b2 * st["nu"] + (1 - b2) * R * R
            u_low = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
            u = (P @ u_low if left else u_low @ P.T) * oc.galore_scale
            if oc.weight_decay > 0:
                u = u + oc.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), \
                {"P": P, "mu": m, "nu": v}

        paired = jax.tree_util.tree_map_with_path(
            lambda path, p, g, st: upd(path, p, g, st),
            params, grads, state["leaves"],
            is_leaf=lambda x: isinstance(x, jnp.ndarray))
        # unzip (params, state) tuples
        new_params = jax.tree.map(lambda t: t[0], paired,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_leaves = jax.tree.map(lambda t: t[1], paired,
                                  is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"leaves": new_leaves, "step": step}, \
            {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


def make(oc: OptimizerConfig) -> Optimizer:
    return {"adamw": adamw, "adam8bit": adam8bit,
            "galore_adamw": galore_adamw}[oc.name](oc)
