"""Blockwise 8-bit state quantization (Dettmers et al. [9], as integrated by
the paper's "8-bit SLTrain" §5.1). Symmetric linear code for the signed
first moment, non-negative linear code for the second moment. The Pallas
`adam8bit` kernel implements the same codec fused with the update; this is
the XLA reference."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_blockwise(x, block: int = 256, signed: bool = True):
    """x: any-shape float → (codes int8, scales f32 per block, orig_len)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    if signed:
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        codes = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    else:
        scale = jnp.max(blocks, axis=1, keepdims=True) / 255.0
        codes = jnp.round(blocks / jnp.maximum(scale, 1e-12)) - 128.0
        codes = codes.astype(jnp.int8)
    return codes, scale[:, 0], n


def dequantize_blockwise(codes, scales, n, shape, signed: bool = True):
    blocks = codes.astype(jnp.float32)
    if not signed:
        # half-quant-step floor: zero-quantized second moments explode the
        # Adam update (see kernels/adam8bit.py)
        blocks = jnp.maximum(blocks + 128.0, 0.5)
    flat = (blocks * scales[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)
