from repro.optim import optimizers, quant, schedule  # noqa: F401
