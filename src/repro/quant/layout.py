"""Quantized tile-CSR layout for the ``exec_mode="quant"`` decode path.

The bf16 sparse-decode kernel reads, per nonzero, a f32 tile value (4 B)
plus int32 local row/col indices (8 B) — 12·δ B/cell. This layout stores:

* ``qv_t``    int8  (nkt, nnt, cap) — quantized codes baked in tile order
* ``rows_q``  int16 (nkt, nnt, cap) — tile-LOCAL row index (< 128)
* ``cols_q``  int16 (nkt, nnt, cap) — tile-local col index (< 128)
* ``qscale``  f32   (nnt, TILE)     — per-output-channel scales, blocked
                                      by column tile so the kernel's
                                      (1, TILE) BlockSpec delivers
                                      exactly the slice tile j needs

i.e. 1 + 2 + 2 = 5 B per nonzero (≈ 5·δ B/cell, a 2.4× cut) plus a
d_out-sized f32 scale vector amortized over all nonzeros of the matrix.
Geometry reuses ``support.tile_cap`` / ``kernels.ops.prepare_tile_consts``
exactly, so quantized shapes are as deterministic as the fused-training
consts (dry-run twins, per-layer stacking, elastic restore all hold).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import support as support_lib

TILE = support_lib.TILE

# bytes per NONZERO read by each sparse decode path (the modeled HBM
# accounting benchmarks/quant_bench.py and the serve demo report):
#   bf16 tile-CSR: f32 value + int32 row + int32 col
#   int8 layout:   int8 code + int16 row + int16 col
BYTES_PER_NNZ_BF16 = 4 + 4 + 4
BYTES_PER_NNZ_INT8 = 1 + 2 + 2


def channel_scales(W: np.ndarray, *, clip_percentile: float | None = None
                   ) -> np.ndarray:
    """Symmetric per-output-channel int8 scales for a dense-equivalent
    (d_in, d_out) weight: absmax over each column / 127, optionally
    clipped to the ``clip_percentile``-th percentile of the column's
    |values| (outlier suppression). Returns (d_out,) f32, floored away
    from zero so all-zero channels still divide cleanly."""
    absW = np.abs(np.asarray(W, np.float32))
    if clip_percentile is not None:
        amax = np.percentile(absW, clip_percentile, axis=0)
    else:
        amax = absW.max(axis=0)
    return (np.maximum(amax, 1e-8) / 127.0).astype(np.float32)


def quantize_values(v: np.ndarray, cols: np.ndarray, scales: np.ndarray
                    ) -> np.ndarray:
    """Flat COO sparse values → int8 codes against their column's scale.
    Codes clip to ±127 (symmetric; -128 unused so negation round-trips)."""
    q = np.round(np.asarray(v, np.float32) / scales[np.asarray(cols)])
    return np.clip(q, -127, 127).astype(np.int8)


def dequantize_values(qv: np.ndarray, cols: np.ndarray, scales: np.ndarray
                      ) -> np.ndarray:
    """Inverse of :func:`quantize_values` (f32)."""
    return qv.astype(np.float32) * scales[np.asarray(cols)]


def build_quant_consts(rows: np.ndarray, cols: np.ndarray, qv: np.ndarray,
                       scales: np.ndarray, d_in: int, d_out: int,
                       delta: float, support_kind: str) -> dict:
    """COO support + int8 codes + (d_out,) scales → the quantized
    tile-CSR const dict {qv_t, rows_q, cols_q, qscale} at the
    deterministic ``support.tile_cap`` capacity. Padding slots carry
    qv == 0 at local (0, 0) — they contribute exactly 0 through the
    kernel; padded columns past d_out get scale 1.0 (never referenced)."""
    from repro.kernels import ops
    cap = support_lib.tile_cap(d_in, d_out, delta, support_kind)
    tiles = ops.prepare_tile_consts(np.asarray(rows), np.asarray(cols),
                                    d_in, d_out, pad=cap)
    perm = np.asarray(tiles["perm"])
    qv_flat = np.asarray(qv, np.int8).reshape(-1)
    qv_t = np.where(perm >= 0, qv_flat[np.maximum(perm, 0)], 0
                    ).astype(np.int8)
    nnt = perm.shape[1]
    sc = np.ones(nnt * TILE, np.float32)
    sc[:d_out] = np.asarray(scales, np.float32)
    return {"qv_t": jnp.asarray(qv_t),
            "rows_q": jnp.asarray(np.asarray(tiles["rows_t"], np.int16)),
            "cols_q": jnp.asarray(np.asarray(tiles["cols_t"], np.int16)),
            "qscale": jnp.asarray(sc.reshape(nnt, TILE))}


def abstract_quant_consts(d_in: int, d_out: int, delta: float,
                          support_kind: str) -> dict:
    """ShapeDtypeStruct twin of :func:`build_quant_consts` (dry-run /
    sharding-spec derivation without a calibrated artifact)."""
    import jax
    sds = jax.ShapeDtypeStruct
    nkt = (d_in + TILE - 1) // TILE
    nnt = (d_out + TILE - 1) // TILE
    cap = support_lib.tile_cap(d_in, d_out, delta, support_kind)
    return {"qv_t": sds((nkt, nnt, cap), jnp.int8),
            "rows_q": sds((nkt, nnt, cap), jnp.int16),
            "cols_q": sds((nkt, nnt, cap), jnp.int16),
            "qscale": sds((nnt, TILE), jnp.float32)}


def sparse_decode_bytes(d_in: int, d_out: int, delta: float,
                        support_kind: str = "row_balanced", *,
                        quant: bool) -> int:
    """Modeled HBM bytes one decode step reads for the SPARSE term of one
    (d_in, d_out) matrix: per-nonzero payload plus, for the quant layout,
    the per-channel f32 scale vector. Excludes the low-rank factors
    (identical bytes on both paths) and tile-cap padding (both layouts
    pad identically, so the ratio is unchanged)."""
    nnz = support_lib.nnz_for(d_in, d_out, delta, support_kind)
    if quant:
        return nnz * BYTES_PER_NNZ_INT8 + d_out * 4
    return nnz * BYTES_PER_NNZ_BF16
