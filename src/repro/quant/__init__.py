"""repro.quant — post-training int8 quantization of SLTrain weights for
serving (ROADMAP open item 2; SLiM arXiv:2410.09615, SLoPe
arXiv:2405.16325).

* :mod:`repro.quant.layout` — the quantized tile-CSR layout: int8 codes
  + int16 tile-local indices at the deterministic ``support.tile_cap``
  geometry, plus per-output-channel f32 scales blocked by column tile,
  and the modeled decode-bytes accounting.
* :mod:`repro.quant.calibrate` — the one-shot activation-free quantizer:
  per-channel symmetric int8 scales on the dense-equivalent W = B·A + S,
  sparse values quantized against them, residual error SVD-folded into
  the bf16 low-rank factors. Also the CLI
  (``python -m repro.quant.calibrate``) that turns a trained checkpoint
  into a versioned quant artifact (ckpt/checkpoint.py).
Submodules import lazily (``from repro.quant import calibrate``) — an
eager package import here would trip runpy's double-import warning under
``python -m repro.quant.calibrate``, the CLI entry point.
"""
