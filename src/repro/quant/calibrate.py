"""One-shot post-training quantizer for SLTrain weights (SLiM-style,
activation-free variant).

Per SLTrain linear (params {B, A, v}, consts {cols[, rows]}):

1. form the dense-equivalent ``W = scale·B·A ⊕ V`` in f32,
2. compute symmetric per-output-channel int8 scales on W (optional
   absmax-clip percentile for outlier suppression),
3. quantize the SPARSE values ``v`` to int8 codes against those scales,
4. fold the residual quantization error ``E = V − dequant(qv)`` into the
   low-rank factors via a rank-preserving SVD correction: the corrected
   ``scale·B'·A'`` is the best rank-r approximation of ``scale·B·A + E``
   (SLiM's saliency trick without activations — B', A' stay bf16 and
   absorb most of the sparse quant error for free),
5. bake the codes into the quantized tile-CSR layout
   (:mod:`repro.quant.layout`) at the deterministic ``support.tile_cap``
   geometry.

:func:`calibrate_tree` walks a whole model's (params, consts) trees —
including layer-stacked leaves, whose supports differ per layer — and
returns the quantized twin: params with B/A replaced, consts with
{qv_t, rows_q, cols_q, qscale} added per linear. Everything else
(embeds, norms, lm_head, the flat bf16 ``v``) passes through unchanged,
so the artifact serves any exec_mode and round-trips through the
versioned export in ckpt/checkpoint.py bit-exactly.

CLI (the ci_check.sh quant smoke):

  PYTHONPATH=src python -m repro.quant.calibrate --arch llama_60m \\
      --smoke --ckpt-dir /path/to/train/ckpt --out /path/to/artifact
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.quant import layout as qlayout


def _is_sl_linear(p) -> bool:
    return isinstance(p, dict) and {"B", "A", "v"} <= set(p.keys())


def _flat_support(v: np.ndarray, c: dict) -> Tuple[np.ndarray, np.ndarray,
                                                   np.ndarray]:
    """(rows, cols, values) flat COO for one UNSTACKED linear's support.
    Row-balanced stores implicit rows (iota per row, k entries each) —
    the same flatten order init_params used to reshape cols to (d_in, k)."""
    if "rows" in c:
        rows = np.asarray(c["rows"]).reshape(-1)
        cols = np.asarray(c["cols"]).reshape(-1)
    else:
        cols2 = np.asarray(c["cols"])
        d_in, k = cols2.shape
        rows = np.repeat(np.arange(d_in, dtype=np.int32), k)
        cols = cols2.reshape(-1)
    return rows, cols, np.asarray(v, np.float32).reshape(-1)


def quantize_linear(p: dict, c: dict, *, alpha: float, delta: float,
                    support_kind: str,
                    clip_percentile: Optional[float] = None,
                    fold_error: bool = True) -> Tuple[dict, dict, dict]:
    """Quantize ONE unstacked SLTrain linear.

    Returns (new_params, quant_consts, stats): params keep {B, A, v}
    dtypes/shapes (B/A error-folded when ``fold_error``), quant_consts is
    the {qv_t, rows_q, cols_q, qscale} dict from
    :func:`layout.build_quant_consts`, and stats carries the max |W −
    W_quant| reconstruction error of the dense equivalent (after fold)."""
    B = np.asarray(p["B"], np.float32)
    A = np.asarray(p["A"], np.float32)
    d_in, r = B.shape
    d_out = A.shape[1]
    scale = alpha / r
    rows, cols, vf = _flat_support(p["v"], c)

    BA = scale * (B @ A)
    W = BA.copy()
    W[rows, cols] += vf
    scales = qlayout.channel_scales(W, clip_percentile=clip_percentile)
    qv = qlayout.quantize_values(vf, cols, scales)
    deq = qlayout.dequantize_values(qv, cols, scales)

    B2, A2 = B, A
    if fold_error:
        # scale·B'·A' := best rank-r approximation of scale·B·A + E, so
        # the dequantized serve-time weight scale·B'·A' + dequant(qv)
        # lands as close to W as a rank-r correction can get
        E = np.zeros_like(BA)
        E[rows, cols] = vf - deq
        U, S, Vt = np.linalg.svd(BA + E, full_matrices=False)
        root = np.sqrt(np.maximum(S[:r], 0.0) / scale)
        B2 = U[:, :r] * root[None, :]
        A2 = root[:, None] * Vt[:r]

    Wq = scale * (B2 @ A2)
    Wq[rows, cols] += deq
    stats = {"nnz": int(vf.size),
             "max_abs_err": float(np.max(np.abs(W - Wq))),
             "rms_err": float(np.sqrt(np.mean((W - Wq) ** 2)))}
    new_p = dict(p)
    new_p["B"] = jnp.asarray(B2).astype(p["B"].dtype)
    new_p["A"] = jnp.asarray(A2).astype(p["A"].dtype)
    qc = qlayout.build_quant_consts(rows, cols, qv, scales, d_in, d_out,
                                    delta, support_kind)
    return new_p, qc, stats


def _quantize_stacked(p: dict, c: dict, *, alpha: float, delta: float,
                      support_kind: str,
                      clip_percentile: Optional[float],
                      fold_error: bool, stats: dict) -> Tuple[dict, dict]:
    """Quantize one linear whose leaves may carry leading stack dims
    (layer/period stacking prepends axes to every leaf; supports differ
    per slice). Loops host-side over the flattened lead and re-stacks —
    shapes are deterministic (tile_cap), so the stack is always ragged-free."""
    B = np.asarray(p["B"])
    lead = B.shape[:-2]
    if not lead:
        new_p, qc, st = quantize_linear(
            p, c, alpha=alpha, delta=delta, support_kind=support_kind,
            clip_percentile=clip_percentile, fold_error=fold_error)
        stats["n_matrices"] += 1
        stats["nnz"] += st["nnz"]
        stats["max_abs_err"] = max(stats["max_abs_err"], st["max_abs_err"])
        return new_p, {**c, **qc}
    n = int(np.prod(lead))

    def slc(leaf):
        a = np.asarray(leaf)
        return a.reshape((n,) + a.shape[len(lead):])

    ps = {k: slc(v) for k, v in p.items()}
    cs = {k: slc(v) for k, v in c.items()}
    out_p, out_q = [], []
    for i in range(n):
        pi = {k: v[i] for k, v in ps.items()}
        ci = {k: v[i] for k, v in cs.items()}
        np_i, qc_i = _quantize_stacked(
            pi, ci, alpha=alpha, delta=delta, support_kind=support_kind,
            clip_percentile=clip_percentile, fold_error=fold_error,
            stats=stats)
        out_p.append(np_i)
        out_q.append(qc_i)

    def restack(dicts):
        return {k: jnp.asarray(np.stack([np.asarray(d[k]) for d in dicts])
                               .reshape(lead + np.asarray(dicts[0][k]).shape))
                for k in dicts[0]}

    new_p = restack(out_p)
    new_p = {k: v.astype(p[k].dtype) if k in ("B", "A", "v") else v
             for k, v in new_p.items()}
    return new_p, restack(out_q)


def calibrate_tree(params, consts, *, alpha: float, delta: float,
                   support_kind: str = "row_balanced",
                   clip_percentile: Optional[float] = None,
                   fold_error: bool = True):
    """Walk a model's (params, consts) trees and quantize every SLTrain
    linear. Returns (new_params, new_consts, stats); non-linear leaves
    (embeds, norms, dense w) and existing consts pass through untouched."""
    stats = {"n_matrices": 0, "nnz": 0, "max_abs_err": 0.0,
             "format": "sltrain-quant-v1"}

    def walk(p, c):
        if _is_sl_linear(p):
            return _quantize_stacked(
                p, c if isinstance(c, dict) else {}, alpha=alpha,
                delta=delta, support_kind=support_kind,
                clip_percentile=clip_percentile, fold_error=fold_error,
                stats=stats)
        new_p, new_c = {}, {}
        csub = c if isinstance(c, dict) else {}
        for k, v in p.items():
            if isinstance(v, dict):
                sp, sc = walk(v, csub.get(k, {}))
                new_p[k] = sp
                if sc:
                    new_c[k] = sc
            else:
                new_p[k] = v
        for k, v in csub.items():          # consts with no param sibling
            if k not in new_c:
                new_c[k] = v
        return new_p, new_c

    new_params, new_consts = walk(params, consts)
    return new_params, new_consts, stats


def calibrate_model(cfg, params, consts, **kw):
    """Config-driven wrapper: alpha/delta/support_kind from cfg.param."""
    pc = cfg.param
    if pc.mode != "sltrain":
        raise ValueError(f"quant calibration targets mode='sltrain' "
                         f"(got {pc.mode!r})")
    return calibrate_tree(params, consts, alpha=pc.alpha, delta=pc.delta,
                          support_kind=pc.support_kind, **kw)


def main(argv=None):
    import argparse
    import dataclasses

    import jax

    from repro.ckpt import checkpoint as ckpt_lib
    from repro.models import registry

    ap = argparse.ArgumentParser(
        description="one-shot int8 calibration of a trained SLTrain "
                    "checkpoint into a quant serve artifact")
    ap.add_argument("--arch", default="llama_60m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", required=True,
                    help="trained checkpoint dir (repro.launch.train)")
    ap.add_argument("--out", required=True,
                    help="output directory for the quant artifact")
    ap.add_argument("--clip-percentile", type=float, default=None,
                    help="absmax-clip percentile for the channel scales "
                         "(default: exact absmax)")
    ap.add_argument("--no-fold", action="store_true",
                    help="skip the SVD error fold into B/A")
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    if cfg.param.mode != "sltrain":
        cfg = dataclasses.replace(
            cfg, param=dataclasses.replace(cfg.param, mode="sltrain"))
    api = registry.get_api(cfg)
    params, consts = api.init(cfg, jax.random.PRNGKey(0), seed=0)
    cm = ckpt_lib.CheckpointManager(args.ckpt_dir)
    tree, _ = cm.restore({"params": params}, allow_config_change=True)
    qp, qc, stats = calibrate_model(
        cfg, tree["params"], consts,
        clip_percentile=args.clip_percentile, fold_error=not args.no_fold)
    path = ckpt_lib.save_quant_artifact(args.out, qp, qc,
                                        config_hash=cfg.hash(), extra=stats)
    print(f"quant artifact: {stats['n_matrices']} matrices, "
          f"{stats['nnz']} int8 codes, max |W - Wq| = "
          f"{stats['max_abs_err']:.3e} -> {path}")


if __name__ == "__main__":
    main()
