"""Sharded, atomic, async checkpointing with elastic resharding (DESIGN §7).

Layout per checkpoint:   <dir>/step_<N>/
    manifest.json   — step, config hash, data-pipeline state, tree paths
    arrays.npz      — one entry per leaf, keyed by "/"-joined tree path

Guarantees:
  * atomic: written to ``step_<N>.tmp`` then ``os.replace``d — a crash
    mid-write never corrupts the latest checkpoint,
  * async: ``save(..., background=True)`` snapshots to host RAM
    synchronously (so training can mutate params immediately) and writes on
    a daemon thread; ``wait()`` joins before the next save or exit,
  * elastic: leaves are saved *unsharded* (host-gathered); ``restore``
    device_puts onto whatever shardings the new mesh prescribes — a 256-chip
    checkpoint restores onto 512 chips (or 1 CPU) unchanged,
  * self-validating: restore checks the config hash and refuses silent
    architecture drift (pass ``allow_config_change=True`` to migrate).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"
# npz can only store native numpy dtypes; bf16/fp8 leaves are saved as raw
# bit-views with the logical dtype recorded in the manifest.
_BITVIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten_with_paths(tree):
    flat, dtypes = {}, {}

    def name(k):
        if hasattr(k, "key"):
            return str(k.key)
        if hasattr(k, "idx"):
            return str(k.idx)
        return str(k)

    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(name(k) for k in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if str(arr.dtype) in _BITVIEW:
            arr = arr.view(_BITVIEW[str(arr.dtype)])
        flat[key] = arr
    return flat, dtypes


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    def name(k):
        if hasattr(k, "key"):
            return str(k.key)
        if hasattr(k, "idx"):
            return str(k.idx)
        return str(k)

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths_leaves:
        key = _SEP.join(name(k) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != "
                f"model shape {tmpl.shape} (elastic restore reshapes "
                "shardings, never logical shapes)")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, *, config_hash: str = "",
             extra: Optional[Dict[str, Any]] = None,
             background: bool = False) -> str:
        """Snapshot ``tree`` (params/opt_state/whatever pytree) at ``step``."""
        self.wait()
        # Synchronous host snapshot: training may overwrite devices after this.
        flat, dtypes = _flatten_with_paths(tree)
        manifest = {
            "step": int(step),
            "config_hash": config_hash,
            "extra": extra or {},
            "leaves": sorted(flat),
            "dtypes": dtypes,
        }
        final = os.path.join(self.dir, f"step_{step:08d}")

        def write():
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)       # atomic publish
            self._gc()

        if background:
            self._thread = threading.Thread(target=self._guard(write),
                                            daemon=True)
            self._thread.start()
        else:
            write()
        return final

    def _guard(self, fn):
        def run():
            try:
                fn()
            except BaseException as e:   # surfaced on next wait()
                self._error = e
        return run

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, *, step: Optional[int] = None,
                config_hash: str = "", allow_config_change: bool = False,
                shardings=None) -> Tuple[Any, Dict[str, Any]]:
        """Load a checkpoint into the structure of ``template``.

        ``shardings``: optional pytree of NamedSharding matching template —
        this is the elastic-resharding path (checkpoint written under any
        mesh restores onto the current one)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if config_hash and manifest["config_hash"] and \
                manifest["config_hash"] != config_hash:
            if not allow_config_change:
                raise ValueError(
                    f"config hash mismatch: ckpt={manifest['config_hash']} "
                    f"vs model={config_hash}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        for key, dt in manifest.get("dtypes", {}).items():
            if dt in _BITVIEW and key in flat:
                flat[key] = flat[key].view(jnp.dtype(dt))
        tree = _unflatten_like(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, s, tmpl: jax.device_put(
                    arr.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr, s),
                tree, shardings, template)
        else:
            tree = jax.tree.map(
                lambda arr, tmpl: jax.numpy.asarray(
                    arr, dtype=getattr(tmpl, "dtype", None)),
                tree, template)
        return tree, manifest


# -- quant artifacts (repro.quant) ---------------------------------------------
# A quant artifact is a TEMPLATE-FREE export: unlike training checkpoints it
# must restore without re-deriving the model tree (the loader has no calibrated
# consts to init from), so the nested structure is rebuilt from the "/"-joined
# keys themselves. Both trees in the artifact are dict-only, which makes that
# reconstruction exact; the format string is versioned so stale artifacts fail
# loudly instead of mis-dequantizing.
QUANT_FORMAT = "sltrain-quant-v1"


def _nest(flat: Dict[str, np.ndarray]) -> Dict[str, Any]:
    tree: Dict[str, Any] = {}
    for key in sorted(flat):
        node = tree
        parts = key.split(_SEP)
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = flat[key]
    return tree


def save_quant_artifact(directory: str, params: Any, consts: Any, *,
                        config_hash: str = "",
                        extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomically export a calibrated (params, consts) pair as a versioned
    int8 serve artifact: ``<directory>/{manifest.json, arrays.npz}``."""
    pflat, pdt = _flatten_with_paths(params)
    cflat, cdt = _flatten_with_paths(consts)
    flat = {**{"params" + _SEP + k: v for k, v in pflat.items()},
            **{"consts" + _SEP + k: v for k, v in cflat.items()}}
    dtypes = {**{"params" + _SEP + k: v for k, v in pdt.items()},
              **{"consts" + _SEP + k: v for k, v in cdt.items()}}
    manifest = {
        "format": QUANT_FORMAT,
        "config_hash": config_hash,
        "extra": extra or {},
        "leaves": sorted(flat),
        "dtypes": dtypes,
    }
    tmp = directory.rstrip(os.sep) + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.replace(tmp, directory)
    return directory


def load_quant_artifact(directory: str) -> Tuple[Any, Any, Dict[str, Any]]:
    """Load a :func:`save_quant_artifact` export. Returns
    (params, consts, manifest) with every leaf bit-identical to what was
    saved (bf16/fp8 restored through the same bit-view as checkpoints)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    fmt = manifest.get("format")
    if fmt != QUANT_FORMAT:
        raise ValueError(f"unknown quant artifact format {fmt!r} in "
                         f"{directory} (expected {QUANT_FORMAT!r})")
    with np.load(os.path.join(directory, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    for key, dt in manifest["dtypes"].items():
        if dt in _BITVIEW and key in flat:
            flat[key] = flat[key].view(jnp.dtype(dt))
    tree = jax.tree.map(jnp.asarray, _nest(flat))
    return tree.get("params", {}), tree.get("consts", {}), manifest
