"""Sharded, atomic, async checkpointing with elastic resharding (DESIGN §7).

Layout per checkpoint:   <dir>/step_<N>/
    manifest.json   — step, config hash, data-pipeline state, tree paths
    arrays.npz      — one entry per leaf, keyed by "/"-joined tree path

Guarantees:
  * atomic: written to ``step_<N>.tmp`` then ``os.replace``d — a crash
    mid-write never corrupts the latest checkpoint,
  * async: ``save(..., background=True)`` snapshots to host RAM
    synchronously (so training can mutate params immediately) and writes on
    a daemon thread; ``wait()`` joins before the next save or exit,
  * elastic: leaves are saved *unsharded* (host-gathered); ``restore``
    device_puts onto whatever shardings the new mesh prescribes — a 256-chip
    checkpoint restores onto 512 chips (or 1 CPU) unchanged,
  * self-validating: restore checks the config hash and refuses silent
    architecture drift (pass ``allow_config_change=True`` to migrate),
  * corruption-detecting: the manifest records a CRC32 per array (as
    stored, post bit-view) plus a digest over the manifest itself;
    ``restore`` verifies both and raises :class:`CheckpointCorruptError`
    on damage — with ``step=None`` it falls back to the newest INTACT
    step instead of loading garbage (repro.resilience). Checkpoints
    written before checksums existed restore unverified (back-compat).
    Leftover ``step_<N>.tmp`` dirs from a crash mid-publish are ignored
    by ``all_steps()`` and swept on the next save.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import warnings
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification (checksum/digest
    mismatch, unreadable npz/manifest). Distinct from config/shape
    mismatches, which are caller errors and stay ``ValueError``."""


def _crc(arr: np.ndarray) -> int:
    return int(zlib.crc32(np.ascontiguousarray(arr).tobytes()))


def _manifest_digest(manifest: Dict[str, Any]) -> str:
    """sha256 over the canonical manifest JSON, digest field excluded."""
    body = {k: v for k, v in manifest.items() if k != "digest"}
    return hashlib.sha256(
        json.dumps(body, sort_keys=True).encode()).hexdigest()
# npz can only store native numpy dtypes; bf16/fp8 leaves are saved as raw
# bit-views with the logical dtype recorded in the manifest.
_BITVIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten_with_paths(tree):
    flat, dtypes = {}, {}

    def name(k):
        if hasattr(k, "key"):
            return str(k.key)
        if hasattr(k, "idx"):
            return str(k.idx)
        return str(k)

    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(name(k) for k in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if str(arr.dtype) in _BITVIEW:
            arr = arr.view(_BITVIEW[str(arr.dtype)])
        flat[key] = arr
    return flat, dtypes


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    def name(k):
        if hasattr(k, "key"):
            return str(k.key)
        if hasattr(k, "idx"):
            return str(k.idx)
        return str(k)

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths_leaves:
        key = _SEP.join(name(k) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != "
                f"model shape {tmpl.shape} (elastic restore reshapes "
                "shardings, never logical shapes)")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, *, config_hash: str = "",
             extra: Optional[Dict[str, Any]] = None,
             background: bool = False) -> str:
        """Snapshot ``tree`` (params/opt_state/whatever pytree) at ``step``."""
        self.wait()
        self._clean_stale_tmp()
        # Synchronous host snapshot: training may overwrite devices after this.
        flat, dtypes = _flatten_with_paths(tree)
        manifest = {
            "step": int(step),
            "config_hash": config_hash,
            "extra": extra or {},
            "leaves": sorted(flat),
            "dtypes": dtypes,
            # integrity: CRC32 per array AS STORED (post bit-view), plus a
            # digest over the manifest itself — restore() verifies both
            "checksums": {k: _crc(v) for k, v in flat.items()},
        }
        manifest["digest"] = _manifest_digest(manifest)
        final = os.path.join(self.dir, f"step_{step:08d}")

        def write():
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)       # atomic publish
            self._gc()

        if background:
            self._thread = threading.Thread(target=self._guard(write),
                                            daemon=True)
            self._thread.start()
        else:
            write()
        return final

    def _guard(self, fn):
        def run():
            try:
                fn()
            except BaseException as e:   # surfaced on next wait()
                self._error = e
        return run

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def _clean_stale_tmp(self) -> None:
        """Sweep ``step_*.tmp`` leftovers from a crash between the tmp
        write and ``os.replace``. Called at save() start, after wait(),
        so no live writer owns any tmp dir."""
        for d in os.listdir(self.dir):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_verified(self, step: int, verify: bool
                       ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        """Read + integrity-check one step. Raises
        :class:`CheckpointCorruptError` on any damage: unreadable
        manifest/npz (a flipped byte usually breaks the zip member CRC),
        manifest digest mismatch, or per-array checksum mismatch."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except FileNotFoundError:
            raise
        except (OSError, ValueError, UnicodeDecodeError) as e:
            raise CheckpointCorruptError(
                f"{d}/manifest.json unreadable: {e}") from e
        digest = manifest.get("digest")
        if verify and digest is not None and \
                _manifest_digest(manifest) != digest:
            raise CheckpointCorruptError(f"{d}: manifest digest mismatch")
        try:
            with np.load(os.path.join(d, "arrays.npz")) as z:
                flat = {k: z[k] for k in z.files}
        except FileNotFoundError:
            raise
        except Exception as e:   # BadZipFile, zlib.error, ValueError, ...
            raise CheckpointCorruptError(
                f"{d}/arrays.npz unreadable: {e}") from e
        sums = manifest.get("checksums")
        if verify and sums is not None:
            missing = set(sums) - set(flat)
            if missing:
                raise CheckpointCorruptError(
                    f"{d}: arrays.npz is missing {sorted(missing)[:3]}...")
            for key, arr in flat.items():
                want = sums.get(key)
                if want is None or _crc(arr) != int(want):
                    raise CheckpointCorruptError(
                        f"{d}: CRC32 mismatch for leaf {key!r}")
        return flat, manifest

    def verify_step(self, step: int) -> bool:
        """True when ``step`` loads and passes its integrity checks."""
        try:
            self._load_verified(step, verify=True)
            return True
        except (CheckpointCorruptError, FileNotFoundError):
            return False

    def restore(self, template: Any, *, step: Optional[int] = None,
                config_hash: str = "", allow_config_change: bool = False,
                shardings=None, verify: bool = True
                ) -> Tuple[Any, Dict[str, Any]]:
        """Load a checkpoint into the structure of ``template``.

        ``shardings``: optional pytree of NamedSharding matching template —
        this is the elastic-resharding path (checkpoint written under any
        mesh restores onto the current one).

        Integrity (``verify=True``): arrays are checked against the
        manifest's CRC32s and the manifest against its digest. An
        explicit ``step`` that fails raises
        :class:`CheckpointCorruptError`; ``step=None`` walks newest →
        oldest and restores the newest INTACT step (warning per corrupt
        one), raising only when no step survives."""
        if step is None:
            steps = self.all_steps()
            if not steps:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
            flat = manifest = last_err = None
            for s in reversed(steps):
                try:
                    flat, manifest = self._load_verified(s, verify)
                    break
                except CheckpointCorruptError as e:
                    warnings.warn(f"checkpoint step {s} is corrupt ({e}); "
                                  "falling back to the previous step")
                    last_err = e
            if manifest is None:
                raise CheckpointCorruptError(
                    f"no intact checkpoint in {self.dir} "
                    f"({len(steps)} corrupt)") from last_err
        else:
            flat, manifest = self._load_verified(step, verify)
        if config_hash and manifest["config_hash"] and \
                manifest["config_hash"] != config_hash:
            if not allow_config_change:
                raise ValueError(
                    f"config hash mismatch: ckpt={manifest['config_hash']} "
                    f"vs model={config_hash}")
        for key, dt in manifest.get("dtypes", {}).items():
            if dt in _BITVIEW and key in flat:
                flat[key] = flat[key].view(jnp.dtype(dt))
        tree = _unflatten_like(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, s, tmpl: jax.device_put(
                    arr.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr, s),
                tree, shardings, template)
        else:
            tree = jax.tree.map(
                lambda arr, tmpl: jax.numpy.asarray(
                    arr, dtype=getattr(tmpl, "dtype", None)),
                tree, template)
        return tree, manifest


# -- quant artifacts (repro.quant) ---------------------------------------------
# A quant artifact is a TEMPLATE-FREE export: unlike training checkpoints it
# must restore without re-deriving the model tree (the loader has no calibrated
# consts to init from), so the nested structure is rebuilt from the "/"-joined
# keys themselves. Both trees in the artifact are dict-only, which makes that
# reconstruction exact; the format string is versioned so stale artifacts fail
# loudly instead of mis-dequantizing.
QUANT_FORMAT = "sltrain-quant-v1"


def _nest(flat: Dict[str, np.ndarray]) -> Dict[str, Any]:
    tree: Dict[str, Any] = {}
    for key in sorted(flat):
        node = tree
        parts = key.split(_SEP)
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = flat[key]
    return tree


def save_quant_artifact(directory: str, params: Any, consts: Any, *,
                        config_hash: str = "",
                        extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomically export a calibrated (params, consts) pair as a versioned
    int8 serve artifact: ``<directory>/{manifest.json, arrays.npz}``."""
    pflat, pdt = _flatten_with_paths(params)
    cflat, cdt = _flatten_with_paths(consts)
    flat = {**{"params" + _SEP + k: v for k, v in pflat.items()},
            **{"consts" + _SEP + k: v for k, v in cflat.items()}}
    dtypes = {**{"params" + _SEP + k: v for k, v in pdt.items()},
              **{"consts" + _SEP + k: v for k, v in cdt.items()}}
    manifest = {
        "format": QUANT_FORMAT,
        "config_hash": config_hash,
        "extra": extra or {},
        "leaves": sorted(flat),
        "dtypes": dtypes,
    }
    tmp = directory.rstrip(os.sep) + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.replace(tmp, directory)
    return directory


def load_quant_artifact(directory: str) -> Tuple[Any, Any, Dict[str, Any]]:
    """Load a :func:`save_quant_artifact` export. Returns
    (params, consts, manifest) with every leaf bit-identical to what was
    saved (bf16/fp8 restored through the same bit-view as checkpoints)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    fmt = manifest.get("format")
    if fmt != QUANT_FORMAT:
        raise ValueError(f"unknown quant artifact format {fmt!r} in "
                         f"{directory} (expected {QUANT_FORMAT!r})")
    with np.load(os.path.join(directory, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    for key, dt in manifest["dtypes"].items():
        if dt in _BITVIEW and key in flat:
            flat[key] = flat[key].view(jnp.dtype(dt))
    tree = jax.tree.map(jnp.asarray, _nest(flat))
    return tree.get("params", {}), tree.get("consts", {}), manifest
