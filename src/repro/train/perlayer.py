"""repro.train.perlayer — layer-wise backward with in-sweep optimizer
updates (the paper's "per-layer updates" memory path, §5.1 / Appendix F).

The global train step (``train/step.py``) materializes the FULL-model
gradient tree (f32 after clipping) before one ``optimizer.update`` — peak
grad+optimizer-transient HBM is O(P_trainable) no matter how lean the
parameterization is. This engine removes that term:

  1. **Forward once** over the stacked layer scan, saving only the
     per-layer boundary activations (``lm.forward_saving_boundaries``; the
     existing remat policies govern intra-layer residuals).
  2. **Norm sweep** (reverse): re-run one layer's vjp at a time, reduce its
     gradients to a squared-norm contribution immediately, and keep only
     the boundary cotangent. This recovers the exact global gradient norm
     the clip/stat needs *before any update* — the LOMO two-pass trick
     (PAPERS: Lv et al.); it trades one extra backward recompute for never
     holding two layers' grads at once.
  3. **Update sweep** (reverse): re-run each layer's vjp and immediately
     apply that layer's optimizer update through the per-layer slice API
     (``Optimizer.update_slice``, dispatching to the fused ``adam8bit``
     Pallas kernel when ``fused_opt`` — default when the model's
     ``exec_mode == "fused"`` — or the XLA reference otherwise) before the
     next layer's grads exist. Co-resident state is O(one layer) of grads
     + f32 transients instead of O(model).

Update order inside a step is head → layers (top to bottom) → embed; for
Adam-family optimizers this is value-identical to the global step because
no layer's update feeds another layer's gradient within the step (all vjps
re-run from the pre-step params saved in the forward), and the clip scale
comes from the dedicated norm sweep. Checkpoints stay layout-identical to
``update_mode="global"``: params and optimizer state trees are untouched —
only the order in which their leaves are written differs.

Leaves whose optimizer state cannot be sliced along the layer axis
(``stack_state`` returns None: 8-bit quantization blocks straddling layer
boundaries, GaLore projected leaves) take a deferred path — their full
stacked gradient is accumulated through the sweep (as scan outputs) and
updated once at the end, exactly like global mode. These are the small
leaves (norms, odd-sized supports); the big matrices slice.

Tied embeddings are supported without widening the sweep's working set:
the head vjp closes the tied embedding over as a CONSTANT (so the
boundary cotangent is the only thing carried through the layers), and
the head's embed cotangent is recomputed by a dedicated embed-only vjp
at the embed step of each pass — one extra head recompute instead of
holding a V × d f32 cotangent across every layer.

With ``layer_timing`` (an ``obs.metrics.Registry``), the update sweep
stamps a host clock between layer updates via ordered
``jax.experimental.io_callback`` — per-layer update wall time lands in
the ``train.perlayer.layer_update_ms`` histogram (n_layers observations
per step; zero overhead when disabled).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.configs.base import ModelConfig
from repro.models.common import remat_wrap
from repro.models.registry import ModelApi
from repro.obs import metrics as obs_metrics
from repro.optim.optimizers import Optimizer
from repro.train.step import cross_entropy


def _pk(path):
    """Tree path -> tuple of plain str dict keys."""
    out = []
    for k in path:
        key = getattr(k, "key", None)
        out.append(str(key) if key is not None else str(k))
    return tuple(out)


def _sq(tree):
    return sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
               for g in jax.tree.leaves(tree))


def make_perlayer_train_step(cfg: ModelConfig, api: ModelApi,
                             optimizer: Optimizer, *, remat: str = "none",
                             grad_accum: int = 1, aux_coef: float = 0.01,
                             fused_opt: bool | None = None,
                             grad_specs=None,
                             layer_timing: Optional[
                                 obs_metrics.Registry] = None):
    """Returns train_step(params, opt_state, consts, batch) ->
    (params, opt_state, metrics) with per-layer in-sweep updates.

    ``fused_opt`` routes sliced updates through
    ``optimizer.update_slice_fused`` (the Pallas adam8bit kernel) when the
    optimizer provides it; default follows the model's exec mode
    (``cfg.param.exec_mode == "fused"``).

    ``layer_timing`` (a registry, or None = off) turns on per-layer update
    timing: the update sweep hops to host between layer updates
    (ordered ``io_callback``) and records the elapsed wall time per layer
    into ``train.perlayer.layer_update_ms``.

    ``grad_accum > 1`` runs the IN-SWEEP microbatch accumulator: the batch
    splits into microbatches, the forward saves boundaries per microbatch
    (one extra leading axis on the saves), and both reverse sweeps carry
    the STACK of boundary cotangents — at each layer an inner scan re-runs
    that layer's vjp once per microbatch and sums the layer-sized gradient
    before it is reduced to a norm (pass 1) or consumed by the update
    (pass 2). The full gradient tree is never materialized: co-resident
    grads stay O(P_layer), exactly as at grad_accum == 1, and the result
    is token-for-token the global + grad_accum step (sum of per-microbatch
    grads / n_mb, clip norm of the averaged tree).

    ``grad_specs`` (PartitionSpec pytree mirroring params, usually the
    fsdp param specs) pins each layer's sliced gradient to the sliced
    param layout (the stacked leaf's spec minus its layer dim) before the
    in-sweep update — under fsdp the update-sweep's per-layer grads
    reduce-scatter instead of all-reducing, and each device updates only
    its shard. Head/embed whole-leaf grads pin the same way."""
    plapi = api.perlayer
    if plapi is None:
        raise ValueError(f"update_mode='per_layer' needs the per-layer "
                         f"model API; family {cfg.family!r} does not "
                         f"expose one")
    for fn in ("prepare", "update_slice", "leaf_state", "with_leaf_state",
               "stack_state", "unstack_state", "finish"):
        if getattr(optimizer, fn) is None:
            raise ValueError(f"optimizer lacks the per-layer slice API "
                             f"({fn}); update_mode='per_layer' supports "
                             f"adamw, adam8bit and galore_adamw")
    if fused_opt is None:
        fused_opt = cfg.param.exec_mode == "fused"
    upd = optimizer.update_slice
    if fused_opt and optimizer.update_slice_fused is not None:
        upd = optimizer.update_slice_fused
    aux_ct = jnp.float32(aux_coef)
    tied = cfg.tie_embeddings
    n_mb = grad_accum

    from repro.dist.sharding import constrain

    def _spec_of(tree_path):
        """grad spec for a full tree path, or None."""
        if grad_specs is None:
            return None
        node = grad_specs
        for k in tree_path:
            if not isinstance(node, dict) or k not in node:
                return None
            node = node[k]
        return node if isinstance(node, tuple) else None

    def pin_full(g, tree_path):
        s = _spec_of(tree_path)
        return constrain(g, *s) if s is not None else g

    # -- optional per-layer update timing (host hop via io_callback) ------
    if layer_timing is not None:
        _h_layer = layer_timing.histogram(
            "train.perlayer.layer_update_ms",
            buckets=obs_metrics.ms_buckets(),
            help="wall time between consecutive in-sweep layer updates")
        _t_prev = {"ns": 0}

        def _stamp_start():
            _t_prev["ns"] = time.perf_counter_ns()

        def _stamp_layer():
            now = time.perf_counter_ns()
            _h_layer.observe((now - _t_prev["ns"]) / 1e6)
            _t_prev["ns"] = now

    def head_params_of(params):
        """Only the UNTIED head leaves — the tied embedding enters
        head_ce as a separate argument so the sweep can treat it as a
        constant (see the tied-embeddings note in the module docstring)."""
        hp = {"ln_f": params["ln_f"]}
        if not tied:
            hp["lm_head"] = params["lm_head"]
        return hp

    def head_ce(hp, emb, h_top, tokens, scale=None):
        full = dict(hp)
        if tied:
            full["embed"] = emb
        logits = plapi.head(cfg, full, h_top)
        ce = cross_entropy(logits[:, :-1], tokens[:, 1:], cfg.vocab_size)
        # chaos poison (repro.resilience): a NaN scale flows through the
        # head vjp into every boundary cotangent, so BOTH sweeps see
        # genuinely non-finite gradients (and gnorm goes NaN with them)
        return ce if scale is None else ce * scale

    def stack_fns(group):
        """(layer_fn, params_key) for one stacked group."""
        seg = plapi.period if group == "layers" else plapi.dense

        def factory(c_i):
            return remat_wrap(lambda p, x: seg(cfg, p, c_i, x), remat)
        return factory

    def sweep(group, params, consts, bxs, dh, ctx, state):
        """Reverse-scan one stacked group.

        ctx/state None  → norm sweep: returns (dh_bottom, sq_norm_sum).
        ctx/state given → update sweep: applies sliced updates in-scan,
        defers non-sliceable leaves; returns
        (dh_bottom, new_group_params, new_state)."""
        p_sub = params[group]
        c_sub = consts.get(group, {})
        factory = stack_fns(group)
        flat, treedef = jax.tree_util.tree_flatten_with_path(p_sub)
        paths = [_pk(p) for p, _ in flat]
        leaves = [l for _, l in flat]
        n = leaves[0].shape[0]
        norm_pass = ctx is None

        g_specs = None
        if grad_specs is not None and group in grad_specs:
            sflat = jax.tree_util.tree_flatten_with_path(
                grad_specs[group], is_leaf=lambda x: isinstance(x, tuple))[0]
            by = {_pk(p): s for p, s in sflat}
            g_specs = [by.get(p) for p in paths]

        stacked_ls, sliceable = [], []
        if not norm_pass:
            for path, leaf in zip(paths, leaves):
                ls = optimizer.leaf_state(state, (group,) + path)
                st = optimizer.stack_state(ls, leaf, n)
                sliceable.append(st is not None)
                if st is not None:
                    stacked_ls.append(st)
        xs = (p_sub, c_sub, bxs, tuple(stacked_ls))

        def body(carry, xs_i):
            p_i, c_i, x_i, ls_i = xs_i
            f = factory(c_i)
            if norm_pass:
                dh_c, acc = carry
            else:
                dh_c = carry
            if n_mb == 1:
                _, pull = jax.vjp(f, p_i, x_i)
                dp, dx = pull((dh_c, aux_ct))
            else:
                # in-sweep microbatch accumulation: x_i / dh_c carry a
                # leading (n_mb, ...) axis; re-run THIS layer's vjp once
                # per microbatch and sum the layer-sized gradient in f32 —
                # co-resident grads stay O(P_layer), never the full tree
                def mb_body(g_acc, mb):
                    x_m, dh_m = mb
                    _, pull_m = jax.vjp(f, p_i, x_m)
                    dp_m, dx_m = pull_m((dh_m, aux_ct))
                    g_acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), g_acc, dp_m)
                    return g_acc, dx_m
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), p_i)
                dp, dx = jax.lax.scan(mb_body, zeros, (x_i, dh_c))
                dp = jax.tree.map(lambda g: g / n_mb, dp)
            if norm_pass:
                return (dx, acc + _sq(dp)), None
            p_leaves = treedef.flatten_up_to(p_i)
            g_leaves = treedef.flatten_up_to(dp)
            if g_specs is not None:
                # pin the sliced grad to the sliced param layout (stacked
                # spec minus the layer dim): fsdp reduce-scatter point
                g_leaves = [
                    constrain(g, *s[1:]) if s is not None else g
                    for g, s in zip(g_leaves, g_specs)]
            new_p, new_ls, res_g, k = [], [], [], 0
            for j, path in enumerate(paths):
                if sliceable[j]:
                    np_, nls = upd(ctx, p_leaves[j], g_leaves[j], ls_i[k],
                                   full_ndim=leaves[j].ndim)
                    new_p.append(np_)
                    new_ls.append(nls)
                    k += 1
                else:
                    new_p.append(p_leaves[j])
                    res_g.append(g_leaves[j].astype(jnp.float32))
            if layer_timing is not None:
                # ordered host hop: stamps when execution reaches this
                # point in the sweep, so deltas are per-layer update time
                io_callback(_stamp_layer, None, ordered=True)
            return dx, (tuple(new_p), tuple(new_ls), tuple(res_g))

        if norm_pass:
            (dh, acc), _ = jax.lax.scan(body, (dh, jnp.float32(0.0)), xs,
                                        reverse=True)
            return dh, acc

        dh, (new_p, new_ls, res_g) = jax.lax.scan(body, dh, xs, reverse=True)
        # write back: scan stacks ys at their original layer index, so the
        # sliceable outputs already ARE the updated stacked leaves
        out_leaves, k, r = [], 0, 0
        for j, path in enumerate(paths):
            full = (group,) + path
            if sliceable[j]:
                out_leaves.append(new_p[j])
                ls = optimizer.unstack_state(new_ls[k], leaves[j], n)
                state = optimizer.with_leaf_state(state, full, ls)
                k += 1
            else:
                # deferred: the stacked gradient was accumulated through
                # the sweep; update the whole leaf exactly like global mode
                ls = optimizer.leaf_state(state, full)
                np_, nls = upd_full(ctx, leaves[j], res_g[r], ls)
                out_leaves.append(np_)
                state = optimizer.with_leaf_state(state, full, nls)
                r += 1
        return dh, treedef.unflatten(out_leaves), state

    def upd_full(ctx, p, g, ls):
        """Whole-leaf update (head / embed / deferred leaves): a whole
        leaf is its own 'slice', through the same dispatch as the sweep —
        under ``fused_opt`` the Pallas kernel handles these too (its
        wrapper pads arbitrary shapes to whole q-blocks), which is what
        the memory model's zero-HBM-transient claim assumes. GaLore's
        projected leaves only ever land here and galore has no fused
        variant, so they always take the reference path."""
        return upd(ctx, p, g, ls)

    def train_step(params, opt_state, consts, batch):
        tokens = batch["tokens"]
        patches = batch.get("patches")
        chaos_scale = None
        if "chaos_scale" in batch:
            chaos_scale = jnp.mean(batch["chaos_scale"].astype(jnp.float32))

        # ---- forward, saving per-layer boundaries -----------------------
        # grad_accum == 1: one forward, saves are (n_layers, B, S, d).
        # grad_accum > 1: the batch splits into n_mb microbatches scanned
        # sequentially — saves gain a leading mb axis which is then moved
        # INSIDE the layer axis ((n_layers, n_mb, B/n_mb, S, d)) so the
        # reverse sweeps still scan layers on the leading dim.
        if n_mb == 1:
            bnd = plapi.forward_boundaries(cfg, params, consts, batch,
                                           remat=remat)
            tokens_mb = patches_mb = None
        else:
            def split(leaf):
                b = leaf.shape[0]
                return leaf.reshape(n_mb, b // n_mb, *leaf.shape[1:])
            mbs = jax.tree.map(split, batch)
            tokens_mb = mbs["tokens"]
            patches_mb = mbs.get("patches")

            def fwd(_, mb):
                return 0, plapi.forward_boundaries(cfg, params, consts, mb,
                                                   remat=remat)
            _, bnd = jax.lax.scan(fwd, 0, mbs)
            bnd = dict(bnd)
            for k in ("xs", "dense_xs"):
                if bnd.get(k) is not None:
                    bnd[k] = jax.tree.map(lambda a: jnp.moveaxis(a, 0, 1),
                                          bnd[k])
        aux_total = jnp.float32(0.0)
        if bnd["aux_dense"] is not None:
            aux_total = aux_total + bnd["aux_dense"].sum()
        aux_total = aux_total + bnd["aux"].sum()
        if n_mb > 1:
            aux_total = aux_total / n_mb   # mean over microbatches, like
            # the global microbatch scan's parts averaging

        # tied: embed enters the head as a closed-over constant — the
        # head vjp then yields only untied-leaf + boundary cotangents,
        # and the embed's head cotangent is recomputed at the embed step
        # (head_embed_cotangent) instead of being carried down the sweep
        emb0 = params["embed"] if tied else None
        hp = head_params_of(params)

        if n_mb == 1:
            ce, head_pull = jax.vjp(
                lambda hp_, h_: head_ce(hp_, emb0, h_, tokens, chaos_scale),
                hp, bnd["h_top"])

            def head_grads():
                d_head, dh = head_pull(jnp.float32(1.0))
                return d_head, dh
        else:
            def head_grads():
                """Per-microbatch head vjp, summed head-leaf grads / n_mb
                and the STACKED boundary cotangent the sweeps carry."""
                def hb(carry, mb):
                    h_m, t_m = mb
                    g_acc, ce_acc = carry
                    ce_m, pull = jax.vjp(
                        lambda hp_, h_: head_ce(hp_, emb0, h_, t_m,
                                                chaos_scale), hp, h_m)
                    dhp_m, dh_m = pull(jnp.float32(1.0))
                    g_acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), g_acc,
                        dhp_m)
                    return (g_acc, ce_acc + ce_m), dh_m
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), hp)
                (g, ce_sum), dh = jax.lax.scan(
                    hb, (zeros, jnp.float32(0.0)),
                    (bnd["h_top"], tokens_mb))
                return (jax.tree.map(lambda a: a / n_mb, g), dh,
                        ce_sum / n_mb)
            _, _, ce = head_grads()
        loss = ce + aux_coef * aux_total

        def head_embed_cotangent():
            if n_mb == 1:
                _, pull = jax.vjp(
                    lambda e: head_ce(hp, e, bnd["h_top"], tokens,
                                      chaos_scale),
                    params["embed"])
                return pull(jnp.float32(1.0))[0]

            def hb(acc, mb):
                h_m, t_m = mb
                _, pull = jax.vjp(lambda e: head_ce(hp, e, h_m, t_m,
                                                    chaos_scale),
                                  params["embed"])
                return acc + pull(jnp.float32(1.0))[0].astype(jnp.float32), None
            zeros = jnp.zeros(params["embed"].shape, jnp.float32)
            acc, _ = jax.lax.scan(hb, zeros, (bnd["h_top"], tokens_mb))
            return acc / n_mb

        def embed_grad(dh_bottom):
            """Embedding gradient from the bottom boundary cotangent(s)."""
            if n_mb == 1:
                _, pull = jax.vjp(
                    lambda ep: plapi.embed(cfg, ep, tokens, patches),
                    {"embed": params["embed"]})
                return pull(dh_bottom)[0]["embed"]

            def eb(acc, mb):
                if patches_mb is None:
                    t_m, dh_m = mb
                    p_m = None
                else:
                    t_m, p_m, dh_m = mb
                _, pull = jax.vjp(
                    lambda ep: plapi.embed(cfg, ep, t_m, p_m),
                    {"embed": params["embed"]})
                g = pull(dh_m)[0]["embed"].astype(jnp.float32)
                return acc + g, None
            zeros = jnp.zeros(params["embed"].shape, jnp.float32)
            xs_mb = ((tokens_mb, dh_bottom) if patches_mb is None
                     else (tokens_mb, patches_mb, dh_bottom))
            acc, _ = jax.lax.scan(eb, zeros, xs_mb)
            return acc / n_mb

        # ---- pass 1: exact global grad norm (LOMO-style norm sweep) -----
        hg = head_grads()
        d_head, dh = hg[0], hg[1]
        total_sq = _sq(d_head)
        dh1 = dh
        if "layers" in params:
            dh1, acc = sweep("layers", params, consts, bnd["xs"], dh1,
                             None, None)
            total_sq = total_sq + acc
        if "dense_layers" in params:
            dh1, acc = sweep("dense_layers", params, consts,
                             bnd["dense_xs"], dh1, None, None)
            total_sq = total_sq + acc
        d_embed = embed_grad(dh1)
        if tied:
            d_embed = d_embed.astype(jnp.float32) + head_embed_cotangent()
        total_sq = total_sq + _sq(d_embed)
        gnorm = jnp.sqrt(total_sq)

        # ---- pass 2: update sweep (grads exist one layer at a time) -----
        ctx, stats = optimizer.prepare(opt_state, gnorm)
        state = opt_state
        new_params = dict(params)
        if layer_timing is not None:
            io_callback(_stamp_start, None, ordered=True)

        hg = head_grads()   # recompute: don't hold head grads across pass 1
        d_head, dh = hg[0], hg[1]
        for key, g in d_head.items():
            g = pin_full(g, (key,))
            ls = optimizer.leaf_state(state, (key,))
            np_, nls = upd_full(ctx, params[key], g, ls)
            new_params[key] = np_
            state = optimizer.with_leaf_state(state, (key,), nls)

        if "layers" in params:
            dh, new_params["layers"], state = sweep(
                "layers", params, consts, bnd["xs"], dh, ctx, state)
        if "dense_layers" in params:
            dh, new_params["dense_layers"], state = sweep(
                "dense_layers", params, consts, bnd["dense_xs"], dh, ctx,
                state)

        d_embed = embed_grad(dh)
        if tied:
            d_embed = d_embed.astype(jnp.float32) + head_embed_cotangent()
        d_embed = pin_full(d_embed, ("embed",))
        ls = optimizer.leaf_state(state, ("embed",))
        np_, nls = upd_full(ctx, params["embed"], d_embed, ls)
        new_params["embed"] = np_
        state = optimizer.with_leaf_state(state, ("embed",), nls)

        state = optimizer.finish(state, ctx)
        # divergence guard (repro.resilience): gnorm comes from the norm
        # sweep's exact global reduction, so it is non-finite iff ANY
        # layer's gradient is — together with the loss that is the whole
        # detection, two scalar isfinite ops. The in-sweep updates already
        # happened, so select every leaf back to its pre-step value.
        good = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        sel = lambda n, o: jnp.where(good, n, o)                 # noqa: E731
        new_params = jax.tree.map(sel, new_params, params)
        state = jax.tree.map(sel, state, opt_state)
        metrics = {"loss": loss, "ce": ce, "aux": aux_total, **stats,
                   "nonfinite": 1.0 - good.astype(jnp.float32)}
        return new_params, state, metrics

    return train_step
