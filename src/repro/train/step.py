"""train_step / serve_step builders: loss, grad accumulation, remat, and the
jit/sharding glue. Arch-agnostic via the model registry API."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.registry import ModelApi
from repro.optim.optimizers import Optimizer


def cross_entropy(logits, labels, vocab_size: int):
    """Mean next-token CE in fp32; padded vocab tail masked out."""
    lf = logits.astype(jnp.float32)
    if lf.shape[-1] > vocab_size:
        penalty = jnp.where(jnp.arange(lf.shape[-1]) < vocab_size, 0.0, -1e30)
        lf = lf + penalty
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_loss_fn(cfg: ModelConfig, api: ModelApi, remat: str = "none",
                 aux_coef: float = 0.01):
    def loss_fn(params, consts, batch):
        logits, aux = api.apply(cfg, params, consts, batch, remat=remat)
        toks = batch["tokens"]
        ce = cross_entropy(logits[:, :-1], toks[:, 1:], cfg.vocab_size)
        loss = ce + aux_coef * aux
        if "chaos_scale" in batch:
            # fault injection (repro.resilience): a NaN scale poisons the
            # loss through the real vjp so non-finite detection sees
            # genuine NaN gradients, not a synthetic flag. The key is
            # present every step of a chaos run (value 1.0 off-fault) so
            # the pytree structure — and the compiled program — is stable.
            loss = loss * jnp.mean(batch["chaos_scale"].astype(jnp.float32))
        return loss, {"ce": ce, "aux": aux}
    return loss_fn


def nonfinite_gate(loss, grads, new_state, old_state):
    """Skip-step gate: one fused isfinite reduction over loss + grads;
    when anything is non-finite, every leaf of ``new_state`` (a tuple of
    trees, e.g. (params, opt_state)) is replaced by its ``old_state``
    counterpart. Bit-exact identity when finite (``jnp.where`` on a true
    scalar predicate selects the new operand unchanged). Returns
    (gated_state, nonfinite) with ``nonfinite`` a 0/1 f32 metric."""
    good = jnp.isfinite(loss)
    for g in jax.tree.leaves(grads):
        if jnp.issubdtype(g.dtype, jnp.inexact):
            good = good & jnp.isfinite(g).all()
    gated = jax.tree.map(lambda n, o: jnp.where(good, n, o),
                         new_state, old_state)
    return gated, 1.0 - good.astype(jnp.float32)


def make_train_step(cfg: ModelConfig, api: ModelApi, optimizer: Optimizer,
                    *, remat: str = "none", grad_accum: int = 1,
                    aux_coef: float = 0.01, grad_specs=None):
    """Returns train_step(params, opt_state, consts, batch) ->
    (params, opt_state, metrics). With grad_accum > 1 the global batch is
    split into microbatches scanned sequentially (grads averaged) — the
    schedule point straggler mitigation and PP would hook into (DESIGN §7).

    ``grad_specs`` (a PartitionSpec pytree mirroring params — the fsdp
    param specs from ``dist.sharding.param_specs``) pins the gradient
    tree back to the sharded parameter layout before ``optimizer.update``:
    under fsdp this is what turns the backward's gradient all-reduce into
    reduce-scatter + sharded update (each device updates only its param
    shard) instead of all-reduce + replicated update."""
    from repro.dist.sharding import constrain

    if cfg.param.mode == "sltrain" and cfg.param.exec_mode == "quant":
        raise ValueError(
            "exec_mode='quant' is serve-only (int8 codes are not trainable) "
            "— train with dense/sparse/fused and calibrate afterwards "
            "(python -m repro.quant.calibrate)")

    loss_fn = make_loss_fn(cfg, api, remat, aux_coef)
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def pin(grads):
        if grad_specs is None:
            return grads
        return jax.tree.map(lambda g, s: constrain(g, *s), grads,
                            grad_specs)

    def train_step(params, opt_state, consts, batch):
        if grad_accum == 1:
            (loss, parts), grads = vg(params, consts, batch)
        else:
            def micro(carry, mb):
                acc, loss_acc, parts_acc = carry
                (l, pt), g = vg(params, consts, mb)
                return (jax.tree.map(jnp.add, acc, g), loss_acc + l,
                        jax.tree.map(jnp.add, parts_acc, pt)), None

            def split(leaf):
                b = leaf.shape[0]
                return leaf.reshape(grad_accum, b // grad_accum, *leaf.shape[1:])
            micro_batches = jax.tree.map(split, batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            parts0 = {"ce": jnp.float32(0.0), "aux": jnp.float32(0.0)}
            (grads, loss, parts), _ = jax.lax.scan(
                micro, (zeros, jnp.float32(0.0), parts0), micro_batches)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            # average the true ce/aux split like the loss — fabricating
            # aux=0 here hid every MoE router-aux signal under grad accum
            parts = jax.tree.map(lambda x: x / grad_accum, parts)
        grads = pin(grads)
        new_params, new_opt, stats = optimizer.update(grads, opt_state, params)
        # divergence guard (repro.resilience): a non-finite loss/grad must
        # never reach the weights — select the pre-step state instead and
        # report it so the trainer can escalate (skip → rollback)
        (new_params, new_opt), nonfinite = nonfinite_gate(
            loss, grads, (new_params, new_opt), (params, opt_state))
        metrics = {"loss": loss, **parts, **stats, "nonfinite": nonfinite}
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, api: ModelApi, *, greedy: bool = True,
                    temperature: float = 1.0):
    """serve_step(params, consts, tokens, cache, index, block_table, rng) ->
    (next_tokens (B,1), logits, new_cache). One batched decode step.

    ``index`` is a scalar (legacy shared offset) or a (B,) per-slot position
    vector; ``block_table`` (B, blocks_per_slot) switches the cache to the
    paged layout (serve/kv.py)."""
    def serve_step(params, consts, tokens, cache, index, block_table=None,
                   rng=None):
        if block_table is None:
            logits, new_cache = api.decode_step(cfg, params, consts, tokens,
                                                cache, index)
        else:
            logits, new_cache = api.decode_step(cfg, params, consts, tokens,
                                                cache, index,
                                                block_table=block_table)
        last = logits[:, -1, :cfg.vocab_size].astype(jnp.float32)
        if greedy:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rng, last / temperature).astype(jnp.int32)
        return nxt[:, None], logits, new_cache
    return serve_step


def make_prefill_step(cfg: ModelConfig, api: ModelApi, *, greedy: bool = True,
                      temperature: float = 1.0):
    """prefill_step(params, consts, tokens, cache, lengths, block_table,
    rng) -> (first_tokens (B,1), logits, new_cache).

    One jit'd call runs a whole batch of prompts (B, S) through the
    train-style forward, writes K/V for positions [0, S) and samples each
    slot's FIRST output token from logits[s, lengths[s]-1] — replacing
    O(prompt_len) per-token decode dispatches with O(1) per admitted batch.
    Rows are padded to a shared S; padding positions are never attended by
    valid queries (causal mask) and their pages are overwritten by decode
    before they first become visible.

    ``offsets`` (B,) int32 (paged caches only) switches to chunked SUFFIX
    prefill: row s holds the prompt tokens from position offsets[s] on
    (the shared-prefix length), ``lengths`` are SUFFIX lengths, and the
    forward attends the slot's resident prior pages in place — see
    models/lm.prefill_step."""
    def prefill_step(params, consts, tokens, cache, lengths, block_table=None,
                     rng=None, offsets=None):
        logits, new_cache = api.prefill_step(cfg, params, consts, tokens,
                                             cache, block_table=block_table,
                                             offsets=offsets)
        rows = jnp.arange(tokens.shape[0], dtype=jnp.int32)
        last_idx = jnp.clip(lengths - 1, 0, tokens.shape[1] - 1)
        last = logits[rows, last_idx, :cfg.vocab_size].astype(jnp.float32)
        if greedy:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rng, last / temperature).astype(jnp.int32)
        return nxt[:, None], logits, new_cache
    return prefill_step


def make_eval_step(cfg: ModelConfig, api: ModelApi):
    loss_fn = make_loss_fn(cfg, api)

    def eval_step(params, consts, batch):
        loss, parts = loss_fn(params, consts, batch)
        return {"loss": loss, "ppl": jnp.exp(parts["ce"]), **parts}
    return eval_step


def make_compressed_dp_step(cfg: ModelConfig, api: ModelApi,
                            optimizer: Optimizer, mesh, *,
                            pod_axis: str = "pod", block: int = 256,
                            aux_coef: float = 0.01, obs=None):
    """Hierarchical data-parallel train step with int8-compressed cross-pod
    gradient reduction (DESIGN §4: the pod axis is the slow DCI link).

    shard_map over the pod axis: each pod computes grads on its batch shard
    with full precision locally (pjit handles intra-pod sharding inside the
    body on real hardware; here the body is the whole per-pod step), then
    the pods exchange int8-quantized gradients — 4× less DCI wire than f32
    psum, exact local int32 summation of the gathered codes
    (dist/compression.py).

    Params/opt-state are replicated across pods (DP); the batch shards.

    ``obs`` (an ``obs.metrics.Registry``) threads through to
    :func:`repro.dist.compression.psum_tree`, recording the modeled wire
    bytes of every gradient reduction on ``dist.collective_bytes``
    (labeled by compression) — surfaced in the trainer's metrics JSONL.
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist import compat
    from repro.dist.compression import psum_tree

    loss_fn = make_loss_fn(cfg, api, "none", aux_coef)
    n_pods = mesh.shape[pod_axis]

    def body(params, opt_state, consts, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, consts, batch)
        grads = psum_tree(grads, pod_axis, compress=True, block=block,
                          obs=obs, n_participants=n_pods)
        grads = jax.tree.map(lambda g: g / n_pods, grads)
        loss = jax.lax.pmean(loss, pod_axis)
        new_params, new_opt, stats = optimizer.update(grads, opt_state,
                                                      params)
        # post-psum grads are identical on every pod, so the gate (and its
        # skip decision) is replicated — no pod diverges from the others
        (new_params, new_opt), nonfinite = nonfinite_gate(
            loss, grads, (new_params, new_opt), (params, opt_state))
        return new_params, new_opt, {"loss": loss, **stats,
                                     "nonfinite": nonfinite}

    rep = P()  # replicated across the pod axis

    def specs_like(tree, leading_batch=False):
        def spec(leaf):
            if leading_batch:
                return P(pod_axis, *([None] * (leaf.ndim - 1)))
            return P(*([None] * leaf.ndim))
        return jax.tree.map(spec, tree)

    def step(params, opt_state, consts, batch):
        return compat.shard_map(
            body, mesh=mesh,
            in_specs=(specs_like(params), specs_like(opt_state),
                      specs_like(consts), specs_like(batch, True)),
            out_specs=(specs_like(params), specs_like(opt_state),
                       {"loss": rep, "grad_norm": rep, "lr": rep,
                        "nonfinite": rep}),
            check_vma=False,
        )(params, opt_state, consts, batch)

    return step
