"""Training loop: logging, checkpoint/restart, preemption handling,
straggler watchdog, fault injection + divergence recovery (DESIGN §7).

The loop is deliberately framework-grade rather than script-grade:
  * resume-from-latest is the default (idempotent relaunch == restart),
  * SIGTERM/SIGINT triggers a synchronous checkpoint then exit(42) so a
    cluster scheduler can requeue the job (preemption safety),
  * a per-step deadline watchdog flags stragglers; the mitigation hook
    (re-dispatching the slow host's shard) is pluggable — on a single host
    we log and continue, on a fleet the launcher wires in spares,
  * ``fault_hook(step)`` lets tests inject crashes at exact steps to prove
    kill/resume bit-exactness (tests/test_fault_tolerance.py).

Resilience (repro.resilience; tests/test_fault_tolerance.py):
  * **fault matrix** — pass ``chaos=ChaosEngine.parse(spec)`` (launcher
    flag ``--chaos``, e.g. ``"kill@3,nonfinite@5,straggler@4:50"``) and
    the loop deterministically injects process kills (exit 43),
    NaN-poisoned losses, corrupted checkpoint bytes, corrupted data
    batches, and straggler sleeps at exact steps,
  * **escalation policy** — every step's jitted program carries a
    non-finite gate (train/step.py, train/perlayer.py): a NaN/inf loss or
    gradient never reaches the weights (the update is skipped bit-exactly
    in-jit) and is reported via ``metrics["nonfinite"]``. After
    ``max_skips`` consecutive skipped steps the trainer ROLLS BACK to the
    newest intact checkpoint and skips the data cursor forward
    (``rollback_data_skip`` batches, doubling per rollback — the retry
    backoff); after ``max_rollbacks`` rollbacks (``--max-rollbacks``) it
    gives up loudly,
  * **corrupt batches** — host-side token validation drops out-of-range
    batches and advances the cursor (bounded retries),
  * **checksummed checkpoints** — restore verifies per-array CRC32s +
    the manifest digest and falls back to the newest intact step
    (ckpt/checkpoint.py), so a flipped byte costs one ckpt_every of
    progress, not the run.
  Every recovery event lands on the obs registry:
  ``resilience.faults_injected{kind}``, ``resilience.nonfinite_steps``,
  ``resilience.rollbacks``, ``resilience.bad_batches``, plus
  ``resilience.rollback``/``resilience.restore`` trace spans.
"""
from __future__ import annotations

import contextlib
import signal
import sys
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import roofline
from repro.ckpt.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.configs.base import TrainConfig
from repro.core import relora as relora_lib
from repro.data.pipeline import SyntheticC4
from repro.models import registry
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.optim import optimizers
from repro.train import step as step_lib


def _make_relora_merge(cfg):
    """ReLoRA restart (paper eq. (1) / baseline [32]): at each period end,
    merge BA into W0, re-init the factors, and ZERO the factors' Adam
    moments (the optimizer-state reset the paper's schedule requires).

    The merge scale is alpha / r_eff PER MATRIX (r_eff = B.shape[-1], the
    rank Builder.linear actually allocated after the min(d_in, d_out)//2
    cap) — the same convention apply_linear uses in the forward. A global
    alpha/rank here would merge small (capped) matrices at the wrong
    magnitude."""
    alpha = cfg.param.alpha

    def merge(params, opt_state, key):
        is_relora = lambda t: isinstance(t, dict) and \
            {"W0", "B", "A"} <= set(t.keys())

        leaves_done = []

        def walk(t, k):
            if is_relora(t):
                k, sub = jax.random.split(k)
                merged = relora_lib.merge(t, sub, alpha / t["B"].shape[-1])
                leaves_done.append(True)
                return merged, k
            if isinstance(t, dict):
                out = {}
                for name in t:
                    out[name], k = walk(t[name], k)
                return out, k
            return t, k

        new_params, _ = walk(params, key)

        new_opt = dict(opt_state)
        if "mu" in opt_state:
            def reset(tree):
                def go(m, p):
                    if isinstance(p, dict) and {"W0", "B", "A"} <= set(p):
                        out = dict(m)
                        out["B"] = jnp.zeros_like(m["B"])
                        out["A"] = jnp.zeros_like(m["A"])
                        return out
                    if isinstance(p, dict):
                        return {n: go(m[n], p[n]) for n in p}
                    return m
                return go(tree, params)
            new_opt["mu"] = reset(opt_state["mu"])
            new_opt["nu"] = reset(opt_state["nu"])
        return new_params, new_opt

    return merge


@dataclass
class TrainerState:
    params: Any
    opt_state: Any
    consts: Any
    step: int = 0


@dataclass
class StepTimeWatchdog:
    """Flags steps slower than ``factor`` × the rolling median (straggler
    detection). The *response* is a callback so deployments can re-dispatch
    the straggler's data shard to a hot spare (DESIGN §7)."""
    factor: float = 3.0
    window: int = 32
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    times: List[float] = field(default_factory=list)
    flagged: List[int] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = float(np.median(self.times))
        slow = len(self.times) >= 8 and dt > self.factor * med
        if slow:
            self.flagged.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, med)
        return slow


class Trainer:
    def __init__(self, tc: TrainConfig, *, mesh=None, log_fn=print,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 chaos=None, max_skips: int = 2, max_rollbacks: int = 2,
                 rollback_data_skip: int = 1,
                 obs: Optional[obs_metrics.Registry] = None,
                 trace: Optional[obs_trace.Trace] = None,
                 metrics_out: Optional[str] = None,
                 layer_timing: bool = False):
        self.tc = tc
        self.mesh = mesh
        self.log = log_fn
        self.fault_hook = fault_hook
        # -- resilience policy (module docstring: escalation policy) --
        self.chaos = chaos
        self.max_skips = max_skips
        self.max_rollbacks = max_rollbacks
        self.rollback_data_skip = rollback_data_skip
        self._skip_streak = 0
        self._rollbacks = 0
        self.cfg = tc.model
        self.api = registry.get_api(self.cfg)
        self.optimizer = optimizers.make(tc.optim)
        self.ckpt = CheckpointManager(tc.ckpt_dir, keep=tc.keep_ckpts)
        self.data = SyntheticC4(self.cfg.vocab_size, tc.seq_len,
                                tc.global_batch, seed=tc.seed)
        self.watchdog = StepTimeWatchdog()
        self._preempted = False
        self.metrics_history: List[Dict[str, float]] = []

        # -- observability (repro.obs): own registry per trainer so
        # side-by-side runs (sweeps, tests) never share counters; pass a
        # shared one to aggregate. Trace defaults disabled = no-op spans.
        self.obs = obs if obs is not None else obs_metrics.Registry()
        self.trace = trace if trace is not None \
            else obs_trace.Trace(enabled=False)
        self.metrics_out = metrics_out
        self._chips = 1 if mesh is None else int(mesh.devices.size)
        self._c_steps = self.obs.counter("train.steps")
        self._c_tokens = self.obs.counter(
            "train.tokens", help="tokens consumed (global batch x seq)")
        self._g_loss = self.obs.gauge("train.loss")
        self._g_lr = self.obs.gauge("train.lr")
        self._g_gnorm = self.obs.gauge("train.grad_norm")
        self._g_tps = self.obs.gauge(
            "train.tokens_per_sec", help="tokens / (dispatch + sync) time")
        self._g_mfu = self.obs.gauge(
            "train.mfu", help="6ND model-FLOPs utilisation vs chip peak "
            "(analysis.roofline.train_mfu)")
        self._h_step = self.obs.histogram(
            "train.step_ms", buckets=obs_metrics.ms_buckets())
        phase_h = self.obs.histogram(
            "train.phase_ms", buckets=obs_metrics.ms_buckets(),
            help="per-step phase split: data | dispatch | sync")
        self._h_phase = {k: phase_h.labels(phase=k)
                         for k in ("data", "dispatch", "sync")}
        self._c_nonfinite = self.obs.counter(
            "resilience.nonfinite_steps",
            help="steps whose update was skipped (non-finite loss/grads)")
        self._c_rollbacks = self.obs.counter(
            "resilience.rollbacks",
            help="rollbacks to the newest intact checkpoint")
        self._c_bad_batches = self.obs.counter(
            "resilience.bad_batches",
            help="corrupt data batches dropped by host-side validation")
        if self.chaos is not None:
            self.chaos.bind(self.obs)

        self._layer_timing = layer_timing
        self._train_step = self._build_train_step(grad_specs=None)
        self._relora_merge = jax.jit(_make_relora_merge(self.cfg)) \
            if self.cfg.param.mode == "relora" else None

    def _build_train_step(self, *, grad_specs):
        """Build the jitted step for the configured update_mode.

        Called once at construction (grad_specs=None) and again from
        ``_place`` when ``sharding.fsdp`` is set — the fsdp param specs
        only exist once the param tree does, and the step closes over
        them to pin gradients to the sharded layout (reduce-scatter)."""
        tc = self.tc
        if tc.sharding.update_mode == "per_layer":
            from repro.train import perlayer
            return jax.jit(perlayer.make_perlayer_train_step(
                self.cfg, self.api, self.optimizer,
                remat=tc.sharding.remat,
                grad_accum=tc.sharding.grad_accum,
                grad_specs=grad_specs,
                layer_timing=self.obs if self._layer_timing else None))
        if tc.sharding.update_mode != "global":
            raise ValueError(f"unknown update_mode "
                             f"{tc.sharding.update_mode!r}: expected "
                             f"'global' or 'per_layer'")
        if tc.sharding.pod_grad_compression and self.mesh is not None \
                and "pod" in self.mesh.axis_names:
            # int8-compressed cross-pod DP (dist/compression.py); wire
            # counters land on this trainer's registry -> metrics JSONL
            return jax.jit(step_lib.make_compressed_dp_step(
                self.cfg, self.api, self.optimizer, self.mesh,
                obs=self.obs))
        return jax.jit(step_lib.make_train_step(
            self.cfg, self.api, self.optimizer,
            remat=tc.sharding.remat, grad_accum=tc.sharding.grad_accum,
            grad_specs=grad_specs))

    # -- state ----------------------------------------------------------------
    def init_state(self) -> TrainerState:
        key = jax.random.PRNGKey(self.tc.seed)
        params, consts = self.api.init(self.cfg, key, seed=self.tc.seed)
        opt_state = self.optimizer.init(params)
        return TrainerState(params, opt_state, consts, step=0)

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _place(self, state: TrainerState) -> TrainerState:
        """Place state on the mesh per the dist.sharding spec engine (no-op
        without a mesh). Params/consts get the param rules; optimizer
        moments inherit the matching param leaf's spec. With
        ``sharding.fsdp`` the specs additionally shard over the fsdp axis
        and the train step is rebuilt to pin gradients to that layout."""
        if self.mesh is None:
            return state
        from repro.dist import sharding as dist_sharding
        mesh = self.mesh
        sh = self.tc.sharding
        fsdp_axes = (sh.fsdp_axis,) if sh.fsdp else ()
        p_specs = dist_sharding.param_specs(state.params, mesh,
                                            fsdp_axes=fsdp_axes)
        if sh.fsdp:
            self._train_step = self._build_train_step(grad_specs=p_specs)
        return TrainerState(
            dist_sharding.place(state.params, mesh, p_specs),
            dist_sharding.place(
                state.opt_state, mesh,
                dist_sharding.opt_state_specs(state.opt_state, p_specs,
                                              mesh, fsdp_axes=fsdp_axes)),
            dist_sharding.place(
                state.consts, mesh,
                dist_sharding.param_specs(state.consts, mesh,
                                          fsdp_axes=fsdp_axes)),
            state.step)

    def save(self, state: TrainerState, background: Optional[bool] = None) -> None:
        bg = self.tc.async_ckpt if background is None else background
        self.ckpt.save(
            state.step,
            {"params": state.params, "opt_state": state.opt_state},
            config_hash=self.cfg.hash(),
            extra={"data": self.data.state_dict()},
            background=bg)

    def restore_or_init(self) -> TrainerState:
        state = self.init_state()
        if self.ckpt.latest_step() is None:
            return state
        try:
            with self.trace.span("resilience.restore", cat="resilience"):
                # step=None: checksum-verified, falls back newest → oldest
                # past corrupt checkpoints (ckpt/checkpoint.py)
                tree, manifest = self.ckpt.restore(
                    {"params": state.params, "opt_state": state.opt_state},
                    config_hash=self.cfg.hash())
        except CheckpointCorruptError as e:
            self.log(f"[trainer] every checkpoint failed verification "
                     f"({e}): starting fresh")
            return state
        self.data.restore(manifest["extra"]["data"])
        latest = int(manifest["step"])
        self.log(f"[trainer] resumed from step {latest}")
        return TrainerState(tree["params"], tree["opt_state"], state.consts,
                            step=latest)

    # -- resilience (module docstring: escalation policy) ---------------------
    def _next_valid_batch(self, step: int):
        """Next data batch, host-validated; corrupt batches (chaos or a
        real pipeline fault) are dropped and the cursor advances."""
        for _ in range(8):
            batch = self.data.next_batch()
            if self.chaos is not None:
                batch = self.chaos.corrupt_batch(step, batch)
            toks = batch["tokens"]
            if toks.dtype.kind in "iu" and \
                    bool(((toks >= 0) & (toks < self.cfg.vocab_size)).all()):
                return batch
            self._c_bad_batches.inc()
            self.log(f"[trainer] corrupt batch at step {step + 1}: "
                     "dropped, data cursor advanced")
        raise RuntimeError("data pipeline produced 8 consecutive corrupt "
                           "batches — not a transient fault, giving up")

    def _rollback(self, reason: str) -> TrainerState:
        """Divergence escalation: restore the newest intact checkpoint and
        skip the data cursor past the offending batches (skip doubles per
        rollback — the retry backoff). Bounded by ``max_rollbacks``."""
        self._rollbacks += 1
        self._c_rollbacks.inc()
        if self._rollbacks > self.max_rollbacks:
            raise RuntimeError(
                f"{reason} persisted through {self.max_rollbacks} "
                "rollbacks — giving up (raise --max-rollbacks or inspect "
                "the data/optimizer)")
        with self.trace.span("resilience.rollback", cat="resilience",
                             n=self._rollbacks):
            self.ckpt.wait()
            state = self.restore_or_init()
            skip = self.rollback_data_skip * (2 ** (self._rollbacks - 1))
            self.data.skip(skip)
        self._skip_streak = 0
        self.log(f"[trainer] rollback #{self._rollbacks} ({reason}): "
                 f"resumed step {state.step}, skipped {skip} data "
                 f"batch(es) forward")
        return self._place(state)

    # -- preemption -----------------------------------------------------------
    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not on main thread (tests)

    # -- loop -------------------------------------------------------------------
    def run(self, steps: Optional[int] = None,
            state: Optional[TrainerState] = None) -> TrainerState:
        tc = self.tc
        total = steps if steps is not None else tc.steps
        if state is None:
            state = self.restore_or_init()
        state = self._place(state)
        self._install_signal_handlers()
        tokens_per_step = tc.global_batch * tc.seq_len
        while state.step < total:
            if self.chaos is not None:
                # injected kills / checkpoint corruption (may raise
                # ChaosKill — a SystemExit(43) the relaunch recovers from)
                self.chaos.train_hook(state.step, ckpt_dir=self.tc.ckpt_dir)
            if self.fault_hook:
                self.fault_hook(state.step)  # test hook: may raise/kill
            with self.trace.span("train.step", cat="train",
                                 step=state.step + 1):
                t0 = time.perf_counter()
                with self.trace.span("train.data", cat="train"):
                    batch_np = self._next_valid_batch(state.step)
                    if self.chaos is not None and self.chaos.wants_poison:
                        # constant pytree: the key rides along EVERY step
                        # (value 1.0 off-fault), so chaos costs one compile
                        batch_np = dict(batch_np)
                        batch_np["chaos_scale"] = np.full(
                            (batch_np["tokens"].shape[0],),
                            self.chaos.poison_scale(state.step), np.float32)
                    batch = {k: jax.numpy.asarray(v)
                             for k, v in batch_np.items()}
                t1 = time.perf_counter()
                with self._mesh_ctx(), \
                        self.trace.span("train.dispatch", cat="train"):
                    params, opt_state, metrics = self._train_step(
                        state.params, state.opt_state, state.consts, batch)
                t2 = time.perf_counter()
                if self.chaos is not None:
                    self.chaos.straggle(state.step)  # inside the dt window
                with self.trace.span("train.sync", cat="train"):
                    jax.block_until_ready(metrics["loss"])
                t3 = time.perf_counter()
            # dt keeps its historical meaning: dispatch + sync (excludes
            # host-side data work) — the watchdog/history currency
            dt = t3 - t1
            self._h_phase["data"].observe((t1 - t0) * 1e3)
            self._h_phase["dispatch"].observe((t2 - t1) * 1e3)
            self._h_phase["sync"].observe((t3 - t2) * 1e3)
            self._h_step.observe(dt * 1e3)
            self._c_steps.inc()
            self._c_tokens.inc(tokens_per_step)
            state = TrainerState(params, opt_state, state.consts,
                                 state.step + 1)
            if self._relora_merge is not None and \
                    state.step % self.cfg.param.relora_period == 0:
                key = jax.random.fold_in(jax.random.PRNGKey(self.tc.seed),
                                         state.step)
                params, opt_state = self._relora_merge(
                    state.params, state.opt_state, key)
                state = TrainerState(params, opt_state, state.consts,
                                     state.step)
                self.log(f"[trainer] ReLoRA merge+restart at {state.step}")
            slow = self.watchdog.observe(state.step, dt)
            row = {k: float(v) for k, v in metrics.items()}
            row.update(step=state.step, dt=dt)
            self.metrics_history.append(row)
            skipped = row.get("nonfinite", 0.0) >= 1.0
            if skipped:
                # the jitted gate already kept the pre-step params/state;
                # here we only account and decide whether to escalate
                self._c_nonfinite.inc()
                self._skip_streak += 1
                self.log(f"[trainer] non-finite loss/grads at step "
                         f"{state.step}: update skipped "
                         f"({self._skip_streak}/{self.max_skips} before "
                         "rollback)")
            else:
                self._skip_streak = 0
            self._g_loss.set(row["loss"])
            if "lr" in row:
                self._g_lr.set(row["lr"])
            if "grad_norm" in row:
                self._g_gnorm.set(row["grad_norm"])
            self._g_tps.set(tokens_per_step / dt if dt > 0 else 0.0)
            self._g_mfu.set(roofline.train_mfu(self.cfg, tokens_per_step,
                                               dt, self._chips))
            if state.step % tc.log_every == 0 or state.step == total:
                # log line reads back from the registry — the gauges ARE
                # the trainer's reporting surface, not a side channel
                self.log(f"[step {state.step:5d}] "
                         f"loss={self._g_loss.value:.4f} "
                         f"lr={self._g_lr.value or 0:.2e} {dt*1e3:.0f}ms "
                         f"{self._g_tps.value:.0f}tok/s "
                         f"mfu={self._g_mfu.value:.4f}"
                         + (" STRAGGLER" if slow else ""))
                if self.metrics_out:
                    self.obs.write_jsonl(self.metrics_out,
                                         extra={"step": state.step})
            if skipped and self._skip_streak >= self.max_skips:
                state = self._rollback("non-finite loss/grads")
                continue
            if self._preempted:
                self.log("[trainer] preemption signal: checkpoint + exit 42")
                self.save(state, background=False)
                self.ckpt.wait()
                sys.exit(42)
            if tc.ckpt_every and state.step % tc.ckpt_every == 0:
                self.save(state)
        self.save(state, background=False)
        self.ckpt.wait()
        return state
