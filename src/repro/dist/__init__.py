"""``repro.dist`` — single owner of distribution concerns (DESIGN §4/§6).

Modules:

* :mod:`repro.dist.compat` — version-portable ``shard_map`` /
  ``make_mesh`` / ``AxisType`` wrappers; grafts the modern jax names onto
  old pins (``install_forward_compat``, run on package import so every
  ``import repro.dist`` makes modern-style call sites work).
* :mod:`repro.dist.sharding` — the PartitionSpec engine (param / batch /
  opt-state / cache specs from pytree paths), mesh construction, and the
  ambient-mesh ``constrain`` helper model code uses.
* :mod:`repro.dist.compression` — int8 cross-pod gradient reduction
  (``int8_psum`` / ``psum_tree``) and the analytic ``wire_bytes`` model.

Everything above this package (models, train, launch, serve, scripts)
talks to meshes, specs, and collectives only through these modules.
"""
from repro.dist import compat

compat.install_forward_compat()

from repro.dist import compression, sharding  # noqa: E402,F401
