"""Version-portable wrappers over the jax distribution APIs.

The repo targets the modern spellings (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.AxisType``) but must run on the pinned jax 0.4.37, which
only ships ``jax.experimental.shard_map.shard_map(check_rep=...)`` and a
``jax.make_mesh`` without ``axis_types``. Everything in the repo (and the
tests) goes through this module — either by calling :func:`shard_map` /
:func:`make_mesh` directly, or via :func:`install_forward_compat`, which
grafts the modern names onto the ``jax`` namespace so modern-style call
sites work unchanged on the old pin.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


class AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` (jax >= 0.5).

    On the 0.4.x pin every mesh axis behaves like ``Auto`` (GSPMD decides
    placement); the enum exists so mesh-construction call sites written
    against the modern API type-check and run.
    """

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _native_shard_map():
    """The best shard_map the installed jax offers, plus its kwarg style."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None and not getattr(fn, "_repro_compat_shim", False):
        return fn, "check_vma"
    from jax.experimental.shard_map import shard_map as exp_shard_map
    return exp_shard_map, "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
              check_rep=None, **kwargs):
    """Portable ``shard_map``: accepts either replication-check spelling.

    ``check_vma`` (jax >= 0.6) and ``check_rep`` (jax <= 0.5) are the same
    knob; pass whichever you like and the installed jax gets the one it
    understands. Remaining kwargs are forwarded verbatim.
    """
    native, knob = _native_shard_map()
    check = check_vma if check_vma is not None else check_rep
    if check is not None:
        kwargs[knob] = check
    return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kwargs)


@functools.lru_cache(maxsize=1)
def _make_mesh_takes_axis_types() -> bool:
    try:
        return "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        return False


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    """Portable ``jax.make_mesh``: drops ``axis_types`` on jax that predates
    it (all axes behave as Auto there anyway)."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and _make_mesh_takes_axis_types():
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


_installed = False


def install_forward_compat() -> None:
    """Graft the modern distribution API names onto ``jax`` when missing.

    Idempotent. After this runs, modern-style call sites —
    ``jax.shard_map(..., check_vma=False)``,
    ``jax.make_mesh(..., axis_types=(jax.sharding.AxisType.Auto,) * n)`` —
    work on the 0.4.x pin. On a jax that already has the real APIs this is
    a no-op, so the shims never shadow native behaviour.
    """
    global _installed
    if _installed:
        return
    _installed = True

    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = AxisType

    if not hasattr(jax, "shard_map"):
        @functools.wraps(shard_map)
        def _shard_map_shim(f, **kwargs):
            return shard_map(f, **kwargs)
        _shard_map_shim._repro_compat_shim = True
        jax.shard_map = _shard_map_shim

    if not _make_mesh_takes_axis_types():
        orig = jax.make_mesh

        def _make_mesh_shim(axis_shapes, axis_names, *, devices=None,
                            axis_types=None):
            kwargs = {"devices": devices} if devices is not None else {}
            return orig(tuple(axis_shapes), tuple(axis_names), **kwargs)

        _make_mesh_shim._repro_compat_shim = True
        _make_mesh_shim.__wrapped__ = orig
        jax.make_mesh = _make_mesh_shim
