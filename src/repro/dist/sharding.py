"""The sharding spec engine: PartitionSpecs from pytree paths (DESIGN §4).

Single owner of every mesh/sharding decision in the repo:

* **Mesh construction** — :func:`make_production_mesh` (16×16 single-pod,
  2×16×16 multi-pod) and :func:`make_local_mesh`, built through the
  version-portable :mod:`repro.dist.compat` layer.
* **Ambient-mesh probing** — :func:`ambient_mesh` / :func:`constrain`, the
  degrading ``with_sharding_constraint`` used inside model code (moved
  here from ``models/common.py`` so model files carry no mesh logic).
* **Spec derivation** — :func:`spec_for_param` maps a pytree path + leaf
  to a PartitionSpec; :func:`param_specs` / :func:`batch_specs` /
  :func:`opt_state_specs` / :func:`cache_specs` lift it over whole trees.

Placement policy (tensor-parallel output sharding + expert parallelism):

* dense ``w (d_in, d_out)`` — shard ``d_out`` over the model axis (the
  forward's output sharding; the unembed all-gathers once per step);
* SLTrain / low-rank factor ``B (d_in, r)`` — replicated (r is tiny; the
  eq.-(2) backward psums r-sized results, see ``core/sltrain.py``);
* factor ``A (r, d_out)`` — shard ``d_out`` over model, matching the
  dense-w output layout so factored and dense layers compose;
* support ``v`` / ``cols`` (row-balanced ``(d_in, k)``) — shard ``d_in``
  over model: the gather in densify is row-local, so the support shards
  with zero cross-device index traffic;
* fused-mode tile consts ``rows_t`` / ``cols_t`` / ``perm``
  ``(nkt, nnt, cap)`` int32 — shard the ``nnt`` (d_out-tile) axis over
  model, matching the A / dense-w output layout so the distributed fused
  vjp reads only local column tiles;
* quantized serve consts (repro.quant) ``qv_t`` / ``rows_q`` / ``cols_q``
  ``(nkt, nnt, cap)`` and ``qscale (nnt, TILE)`` — same ``nnt``-over-model
  placement as the fused tile consts they mirror;
* expert-stacked MoE weights — shard the expert dim over model (EP);
* norms / embeds / biases / routers — replicated.

FSDP (``ShardingConfig.fsdp``): every spec function takes ``fsdp_axes``;
when set, parameters and optimizer state additionally shard over the
data axis — the fsdp axes are appended to the first matrix dim they
divide, composing with the TP rules above without ever using a mesh axis
twice. The matching schedule (all-gather params before use,
reduce-scatter grads before the update) falls out of XLA SPMD once the
train step pins its gradients back to these specs (train/step.py,
train/perlayer.py).

Every rule is guarded: an axis that does not divide the dim falls back to
replication for that dim, never an error (heterogeneous archs × meshes).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat

MODEL_AXIS = "model"
BATCH_AXES = ("pod", "data")


# ---------------------------------------------------------------------------
# Mesh construction (moved from launch/mesh.py)
# ---------------------------------------------------------------------------

def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes,
                            axis_types=(compat.AxisType.Auto,) * len(axes))


def make_local_mesh():
    """Single-device mesh with the same axis names (tests / CPU training)."""
    return compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)


# ---------------------------------------------------------------------------
# Ambient-mesh probing (moved from models/common.py)
# ---------------------------------------------------------------------------

def ambient_mesh():
    """The mesh jit is tracing under, or None (CPU tests / no context)."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m.axis_names:
            return m
    except Exception:
        pass
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m.axis_names:
            return m
    except Exception:
        pass
    return None


def axis_size(mesh, names) -> int:
    """Product of the sizes of ``names`` (a name or tuple) on ``mesh``;
    names absent from the mesh count as 1."""
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    return int(np.prod([mesh.shape[a] for a in names if a in mesh.axis_names]
                       or [1]))


def constrain(x, *spec):
    """with_sharding_constraint that degrades to a no-op when the ambient
    mesh lacks the named axes or the dims don't divide. spec entries are
    axis names, tuples of names, or None, one per dim of x."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    axes = set(mesh.axis_names)
    clean = []
    for dim, s in zip(x.shape, spec):
        names = s if isinstance(s, tuple) else ((s,) if s else ())
        names = tuple(n for n in names if n in axes)
        n = axis_size(mesh, names)
        clean.append(names if (names and dim % n == 0) else None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*clean))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# Path → spec rules
# ---------------------------------------------------------------------------

def _path_keys(path) -> Tuple[str, ...]:
    """Normalize a tree path (DictKey / SequenceKey / plain objects with a
    ``.key`` attribute) to a tuple of strings."""
    out = []
    for k in path:
        key = getattr(k, "key", None)
        if key is None:
            key = getattr(k, "name", None)
        if key is None:
            key = getattr(k, "idx", None)
        out.append(str(key) if key is not None else str(k))
    return tuple(out)


def _guard(dim: int, mesh, names):
    """names (as a tuple, filtered to axes the mesh has) if they divide
    ``dim``, else None (replicate that dim)."""
    if not names:
        return None
    req = names if isinstance(names, tuple) else (names,)
    tup = tuple(n for n in req if n in mesh.axis_names)
    if not tup:
        return None
    n = axis_size(mesh, tup)
    return tup if dim % max(n, 1) == 0 else None


# leaf name → spec of the TRAILING (matrix) dims; leading dims are the
# layer-stack (and expert-stack) axes handled separately.
_REPLICATED_NAMES = frozenset({
    "bias", "ln_attn", "ln_mlp", "ln_attn_post", "ln_mlp_post", "ln_f",
    "q_norm", "k_norm", "embed", "lm_head", "W0",
})


def _base_spec(name: str, keys: Tuple[str, ...], trailing: Tuple[int, ...],
               mesh, model_axis: str):
    """Spec for the trailing (non-stack) dims of one leaf."""
    nd = len(trailing)
    if name in _REPLICATED_NAMES or nd == 0:
        return (None,) * nd
    if name == "w":
        if "router" in keys:                     # routers stay replicated
            return (None,) * nd
        if nd >= 2:                              # dense W: TP output shard
            return (None,) * (nd - 1) + (_guard(trailing[-1], mesh,
                                                model_axis),)
        return (None,) * nd
    if name == "B":                              # (d_in, r): replicated
        return (None,) * nd
    if name == "A":                              # (r, d_out): TP output shard
        return (None,) * (nd - 1) + (_guard(trailing[-1], mesh, model_axis),)
    if name in ("v", "cols", "rows"):
        if nd >= 2:                              # row-balanced (d_in, k):
            return (_guard(trailing[0], mesh,    # shard d_in rows
                           model_axis),) + (None,) * (nd - 1)
        return (None,) * nd                      # iid COO (nnz,): replicate
    if name in ("rows_t", "cols_t", "perm") and nd == 3:
        # fused-mode tile consts (nkt, nnt, cap) int32: shard the nnt
        # (d_out-tile) axis over model, matching the A / dense-w output
        # sharding — each TP shard then addresses only its own column
        # tiles, and the distributed fused vjp (kernels/ops.py) consumes
        # the local slice without an all-gather.
        return (None, _guard(trailing[1], mesh, model_axis), None)
    if name in ("qv_t", "rows_q", "cols_q") and nd == 3:
        # int8 serve consts (repro.quant): same (nkt, nnt, cap) geometry
        # as the fused tile consts, same nnt-over-model placement.
        return (None, _guard(trailing[1], mesh, model_axis), None)
    if name == "qscale" and nd == 2:
        # (nnt, TILE) per-channel scales: blocked by column tile, so the
        # nnt axis shards alongside qv_t's.
        return (_guard(trailing[0], mesh, model_axis), None)
    # everything else is replicated.
    return (None,) * nd


_MATRIX_NDIM = {"w": 2, "B": 2, "A": 2, "cols": 2, "v": 2, "W0": 2,
                "embed": 2, "lm_head": 2,
                # fused tile consts are 3-D (nkt, nnt, cap); anything
                # beyond that is layer/expert stacking
                "rows_t": 3, "cols_t": 3, "perm": 3,
                # quantized serve consts (repro.quant.layout)
                "qv_t": 3, "rows_q": 3, "cols_q": 3, "qscale": 2}


def _append_fsdp(base, trailing, mesh, fsdp_axes, used):
    """Append the fsdp axes to the FIRST trailing (matrix) dim they
    divide, on top of whatever the TP rules already placed there — never
    reusing a mesh axis (``used`` = axes the lead/base spec consumed).
    Returns the augmented trailing spec, or ``base`` unchanged when no
    dim can absorb them (replicate fallback, same contract as _guard)."""
    axes = tuple(a for a in fsdp_axes
                 if a in mesh.axis_names and a not in used)
    if not axes:
        return base
    out = list(base)
    for i, dim in enumerate(trailing):
        cur = out[i] if isinstance(out[i], tuple) else (
            (out[i],) if out[i] else ())
        cand = cur + axes
        if dim % max(axis_size(mesh, cand), 1) == 0:
            out[i] = cand
            return tuple(out)
    return base


def spec_for_param(path, leaf, mesh, *, model_axis: str = MODEL_AXIS,
                   support_layout: Optional[str] = None,
                   fsdp_axes: Tuple[str, ...] = ()) -> P:
    """PartitionSpec for one parameter/const leaf addressed by tree path.

    Handles the layer-stack convention (scan-over-layers prepends a layer
    axis to every leaf) and the expert-stack convention (MoE experts add a
    second leading axis, sharded over the model axis = EP).

    ``support_layout`` disambiguates SLTrain support leaves whose shapes
    collide once layer-stacked — row-balanced ``(d_in, k)`` vs an iid COO
    ``(nnz,)`` stacked to ``(L, nnz)``: pass ``"iid"`` or
    ``"row_balanced"`` when known (:func:`param_specs` infers it from the
    presence of a sibling ``rows`` leaf); None assumes row-balanced, the
    repo default.

    ``fsdp_axes`` (``ShardingConfig.fsdp``) additionally shards the leaf
    over the data axis: the axes are appended to the first MATRIX dim
    they divide, composing with (never displacing, never double-using)
    the TP placement above. Leading layer/expert-stack dims stay
    unsharded — the per-layer sweep slices them — so fsdp lands on the
    within-layer matrix dims the TP rules left room on.
    """
    keys = _path_keys(path)
    name = keys[-1] if keys else ""
    ndim = leaf.ndim
    shape = tuple(leaf.shape)

    base_nd = min(_MATRIX_NDIM.get(name, 1), ndim)
    if name in ("v", "rows", "cols") and ndim >= 1:
        # row-balanced support is 2-D (d_in, k); iid COO support is 1-D
        # (nnz,) — layer stacking makes the two indistinguishable by shape
        if support_layout == "iid" or name == "rows":
            base_nd = 1
        else:
            base_nd = min(2, ndim)

    n_lead = ndim - base_nd
    trailing = shape[n_lead:]

    lead = [None] * n_lead
    used_model = False
    if "experts" in keys and n_lead >= 1:
        # the expert axis is the innermost leading dim (layer stacks are
        # prepended outside it): (L, E, ...) or (E, ...)
        e_spec = _guard(shape[n_lead - 1], mesh, model_axis)
        if e_spec is not None:
            lead[n_lead - 1] = e_spec
            used_model = True

    if used_model:
        base = (None,) * base_nd      # model axis already used for EP
    else:
        base = _base_spec(name, keys, trailing, mesh, model_axis)
    if fsdp_axes and base_nd > 0:
        used = set()
        for s in tuple(lead) + tuple(base):
            used.update(s if isinstance(s, tuple) else ((s,) if s else ()))
        base = _append_fsdp(base, trailing, mesh, fsdp_axes, used)
    return P(*(tuple(lead) + tuple(base)))


def param_specs(params, mesh, *, model_axis: str = MODEL_AXIS,
                fsdp_axes: Tuple[str, ...] = ()):
    """PartitionSpec pytree mirroring ``params`` (works on abstract trees)."""
    all_paths = {_path_keys(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(params)[0]}

    def spec(path, leaf):
        keys = _path_keys(path)
        layout = None
        if keys and keys[-1] in ("v", "cols", "rows"):
            # an iid COO support dict carries a sibling "rows" leaf;
            # row-balanced stores implicit rows and has none
            layout = ("iid" if keys[:-1] + ("rows",) in all_paths
                      else "row_balanced")
        return spec_for_param(path, leaf, mesh, model_axis=model_axis,
                              support_layout=layout, fsdp_axes=fsdp_axes)

    return jax.tree_util.tree_map_with_path(spec, params)


def batch_specs(batch, mesh, batch_axes: Sequence[str] = BATCH_AXES):
    """Shard the leading (batch) dim of every leaf over ``batch_axes``."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        lead = _guard(leaf.shape[0], mesh, axes)
        return P(lead, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def opt_state_specs(opt_state, p_specs, mesh, *,
                    fsdp_axes: Tuple[str, ...] = ()):
    """Specs for an optimizer-state tree.

    Moment trees that mirror the param tree (AdamW's mu/nu) inherit the
    param leaf's spec; quantized / projected state whose shapes diverge
    (8-bit codes+scales, GaLore factors) and scalars are replicated —
    except under fsdp, where those non-mirroring leaves shard their
    leading dim over the fsdp axes when it divides (8-bit code/scale
    blocks are per-leaf flat, so a dim-0 split is always slice-aligned).
    """
    by_path = {}
    for path, spec in jax.tree_util.tree_flatten_with_path(
            p_specs, is_leaf=lambda x: isinstance(x, P))[0]:
        by_path[_path_keys(path)] = spec

    def spec(path, leaf):
        keys = _path_keys(path)
        for i in range(1, len(keys)):
            cand = by_path.get(keys[i:])
            if cand is not None and len(cand) <= leaf.ndim:
                return cand
        if fsdp_axes and leaf.ndim >= 1:
            g = _guard(leaf.shape[0], mesh, tuple(fsdp_axes))
            if g is not None:
                return P(g, *([None] * (leaf.ndim - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(spec, opt_state)


def cache_specs(cache, mesh, batch_axes: Sequence[str] = BATCH_AXES,
                *, model_axis: str = MODEL_AXIS,
                seq_sharded: bool = False, paged: bool = False,
                attn_kernel: str = "paged"):
    """KV-cache specs.

    Contiguous layout (default): leaves are (..., batch, seq, heads,
    head_dim). Batch shards over the batch axes; heads shard over the
    model axis when they divide (the TP attention layout);
    ``seq_sharded=True`` moves the model axis to the sequence dim instead
    (long-context decode).

    Paged layout (``paged=True``, serve/kv.py): leaves are pools
    (..., n_blocks, block_len, heads, head_dim) with no batch dim — every
    slot shares the pool through its block table. Heads shard over the
    model axis; the block and block_len dims stay replicated so any
    device can serve any slot's pages without cross-host index traffic.
    ``attn_kernel`` names the decode read path the layout must serve:

    * ``"gather"`` — the gathered per-slot view inherits the head
      sharding (XLA places the gather per shard);
    * ``"paged"`` — kernels/paged_attention.py grids over the kv-head
      dim, so the SAME head sharding makes each device stream only its
      local heads' blocks; whole GQA q-head groups land with their kv
      head automatically because the wq output sharding divides by the
      identical model-axis factor. The kernel cannot split the sequence
      (block) dims across devices, so ``seq_sharded=True`` is rejected
      here rather than silently de-paging the pools at dispatch.

    The two kernels deliberately share one layout: toggling
    ``attn_kernel`` at serve time never resharded the cache. Copy-on-
    write prefix sharing (serve/kv.py refcounts) composes for free: a
    shared block is shared through the block TABLE (host-side int32), so
    attaching it to more slots never moves pool bytes — the pools keep
    this heads-over-model layout and every reader streams its local
    heads' rows of the same physical block."""
    if paged and attn_kernel == "paged" and seq_sharded:
        raise ValueError(
            "attn_kernel='paged' cannot run seq-sharded: the kernel "
            "streams whole K/V blocks per (slot, head) grid cell, so the "
            "sequence/block dims must stay replicated — use the head-"
            "sharded TP layout (default) or attn_kernel='gather'")
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def spec(leaf):
        if leaf.ndim < 4:
            return P(*([None] * leaf.ndim))
        n_lead = leaf.ndim - 4
        d0, d1, h, _ = leaf.shape[n_lead:]
        if paged:
            tail = (None, None, _guard(h, mesh, model_axis), None)
        elif seq_sharded:
            tail = (_guard(d0, mesh, axes), _guard(d1, mesh, model_axis),
                    None, None)
        else:
            tail = (_guard(d0, mesh, axes), None,
                    _guard(h, mesh, model_axis), None)
        return P(*([None] * n_lead + list(tail)))

    return jax.tree.map(spec, cache)


def constrain_boundary(x, *, seq_sharded: bool = False):
    """Sharding constraint for a per-layer boundary-activation save
    (B, S, d) emitted by ``lm.forward_saving_boundaries``: batch dim over
    the batch axes; with ``seq_sharded`` (cfg.seq_shard_activations) the
    sequence dim additionally shards over the model axis, matching the SP
    residual layout the layer body already pinned — saving the boundary
    must not all-gather what the scan keeps sharded. Degrades to a no-op
    off-mesh (CPU tests)."""
    if seq_sharded:
        return constrain(x, BATCH_AXES, MODEL_AXIS, None)
    return constrain(x, BATCH_AXES, None, None)


def boundary_save_specs(xs, mesh, batch_axes: Sequence[str] = BATCH_AXES,
                        *, model_axis: str = MODEL_AXIS,
                        seq_sharded: bool = False,
                        fsdp_axes: Tuple[str, ...] = ()):
    """Specs for STACKED boundary saves (n_layers, B, S, d): layer dim
    replicated (the reverse sweep slices it layer by layer on every
    device), batch over the batch axes, seq optionally over model (SP).
    Under fsdp, when the batch dim could NOT absorb the batch axes (tiny
    per-host batches), the stacked layer dim shards over the fsdp axes
    instead so the saves still split — never both (no axis reuse)."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def spec(leaf):
        if leaf.ndim < 3:
            return P(*([None] * leaf.ndim))
        n_lead = leaf.ndim - 3
        b, s, _ = leaf.shape[n_lead:]
        bt = _guard(b, mesh, axes)
        seq = _guard(s, mesh, model_axis) if seq_sharded else None
        lead = [None] * n_lead
        if fsdp_axes and n_lead >= 1:
            rem = tuple(a for a in fsdp_axes if a not in (bt or ()))
            g = _guard(leaf.shape[0], mesh, rem) if rem else None
            if g is not None:
                lead[0] = g
        return P(*lead, bt, seq, None)

    return jax.tree.map(spec, xs)


def named_shardings(mesh, spec_tree):
    """Map a PartitionSpec pytree to NamedShardings on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def place(tree, mesh, specs=None):
    """device_put a pytree onto ``mesh`` per a spec tree.

    ``specs`` defaults to the :func:`param_specs` rules — callers placing
    non-param trees (KV caches, optimizer state) pass the matching spec
    tree explicitly. The single placement helper every consumer (trainer,
    serve engine) goes through, so the spec↔sharding pairing lives here.
    """
    if specs is None:
        specs = param_specs(tree, mesh)
    return jax.device_put(tree, named_shardings(mesh, specs))
