"""Gradient-compression collectives for the slow cross-pod (DCI) links.

SLTrain's factored gradients are already small (the eq.-(2) backward psums
r- and k-sized results, ``core/sltrain.py``); what remains expensive at
multi-pod scale is the data-parallel gradient all-reduce over the
inter-pod link. :func:`int8_psum` compresses that exchange ~4× with
block-wise symmetric quantization and EXACT integer summation on the
wire: the block scale is agreed first (a tiny f32 pmax), every pod then
quantizes onto the SAME grid, and the int codes are summed losslessly —
the only error is the one initial quantization step, independent of the
number of participants (no re-quantization cascade).

:func:`wire_bytes` is the analytic model the tests/dry-run use to compare
an f32 ring all-reduce against the compressed exchange, and
:func:`psum_tree` lifts the compressed reduction over gradient pytrees
(``train/step.py:make_compressed_dp_step``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_psum(x, axis_name: str, *, block: int = 256):
    """psum over ``axis_name`` with int8 block-quantized summands.

    Must be called inside ``shard_map``. Three phases:

    1. block-wise absmax, pmax'd over the axis → a SHARED scale per block
       (f32, ``n/block`` elements of wire — negligible);
    2. symmetric quantization onto the shared grid: int8 codes in
       [-127, 127], all-gathered — the wire carries 1 B/elem, the 4×
       reduction :func:`wire_bytes` models;
    3. each participant sums the gathered codes locally in int32 (exact —
       nobody compounds anyone else's rounding) and dequantizes:
       ``sum_codes * scale``.

    Max error per element is one quantization step (absmax/127) from the
    single rounding in phase 2, regardless of participant count.
    """
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)

    absmax = jnp.max(jnp.abs(blocks), axis=1)
    absmax = jax.lax.pmax(absmax, axis_name)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)

    codes = jnp.clip(jnp.round(blocks / scale[:, None]),
                     -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(codes, axis_name)        # int8 on the wire
    total = jnp.sum(gathered.astype(jnp.int32), axis=0)    # exact local sum

    out = total.astype(jnp.float32) * scale[:, None]
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


def wire_bytes(n_elems: int, *, compressed: bool, n_participants: int,
               dtype_bytes: int = 4, block: int = 256) -> float:
    """Per-participant wire bytes for one n-element cross-pod reduction.

    Uncompressed: bidirectional ring all-reduce — each participant moves
    ``2·(p-1)/p`` copies of the buffer at full precision.

    Compressed: the int8 exchange — the shared-scale pmax
    (``(p-1)/p · n/block`` f32), then an all-gather of int8 codes plus the
    per-block scales (each participant receives ``p-1`` remote shards,
    1 B/elem) and a local exact integer sum. At p = 2 that is ~1 B/elem
    against the ring's 4 B/elem — the ≥3× DCI reduction of DESIGN §4.
    """
    p = max(1, int(n_participants))
    n_blocks = (n_elems + block - 1) // block
    if not compressed:
        return 2.0 * (p - 1) / p * n_elems * dtype_bytes
    scale_sync = (p - 1) / p * n_blocks * 4
    code_gather = (p - 1) * n_elems * 1.0
    scale_gather = (p - 1) * n_blocks * 4
    return scale_sync + code_gather + scale_gather


def psum_tree(tree, axis_name: str, *, compress: bool = True,
              block: int = 256, min_size: int = 1024,
              obs=None, n_participants: int = 1):
    """psum every leaf of a pytree over ``axis_name``.

    With ``compress=True``, float leaves of at least ``min_size`` elements
    go through :func:`int8_psum`; small leaves (norm gains, biases) and
    integer leaves stay exact — they are wire-negligible and precision
    matters most for them. Must be called inside ``shard_map``.

    ``obs`` (an ``obs.metrics.Registry``) records the MODELED
    per-participant wire bytes of every reduction on the
    ``dist.collective_bytes`` counter, labeled ``compressed=true|false``.
    The counters increment at trace time — once per compiled step, so
    after the first step they read "wire bytes per traced step" (the
    :func:`wire_bytes` model the HLO-validation test pins to measured
    collectives); ``n_participants`` is the reduction's axis size, which
    shard_map bodies cannot read off the traced mesh themselves.
    """
    c_wire = None
    if obs is not None:
        c_wire = obs.counter(
            "dist.collective_bytes",
            help="modeled per-participant wire bytes of gradient "
                 "reductions (dist.compression.wire_bytes), per traced "
                 "step")

    def reduce_leaf(g):
        comp = (compress and jnp.issubdtype(g.dtype, jnp.floating)
                and g.size >= min_size)
        if c_wire is not None:
            dtype_bytes = jnp.dtype(g.dtype).itemsize \
                if not comp else 4
            c_wire.labels(compressed=str(comp).lower()).inc(
                wire_bytes(g.size, compressed=comp,
                           n_participants=n_participants,
                           dtype_bytes=dtype_bytes, block=block))
        if comp:
            return int8_psum(g, axis_name, block=block)
        return jax.lax.psum(g, axis_name)

    return jax.tree.map(reduce_leaf, tree)
