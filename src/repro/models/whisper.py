"""Whisper-style encoder-decoder transformer backbone.

The audio conv frontend is a STUB (per the assignment): `input_specs()`
provides precomputed frame embeddings (B, encoder_seq, d_model). The
backbone is faithful: LayerNorm (with bias), learned positions, GELU MLP,
MHA with bias, decoder self-attn (cached) + cross-attn to encoder output
(cross K/V cached at prefill). Shape cells apply `seq_len` to the decoder;
the encoder always sees `encoder_seq` frames (DESIGN §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, mlp
from repro.models.common import Builder, apply_linear, layer_norm, stack_layers


def _ln(b: Builder, name: str, d: int):
    return {"w": b.tensor(f"{name}_w", (d,), "ones"),
            "b": b.tensor(f"{name}_b", (d,), "zeros")}


def _apply_ln(p, x, eps):
    return layer_norm(x, p["w"], p["b"], eps)


def _init_enc_block(b: Builder, cfg: ModelConfig):
    params, consts = {}, {}
    params["ln1"] = _ln(b, "ln1", cfg.d_model)
    p, c = attention.init_attention(b.sub("attn"), cfg)
    params["attn"] = p
    if c:
        consts["attn"] = c
    params["ln2"] = _ln(b, "ln2", cfg.d_model)
    p, c = mlp.init_mlp(b.sub("mlp"), cfg, gated=False)
    params["mlp"] = p
    if c:
        consts["mlp"] = c
    return params, consts


def _init_dec_block(b: Builder, cfg: ModelConfig):
    params, consts = _init_enc_block(b, cfg)
    params["ln_x"] = _ln(b, "ln_x", cfg.d_model)
    p, c = attention.init_attention(b.sub("xattn"), cfg, cross=True)
    params["xattn"] = p
    if c:
        consts["xattn"] = c
    return params, consts


def init_whisper(cfg: ModelConfig, key=None, seed: int = 0):
    b = Builder(cfg, key, seed=seed)
    d = cfg.d_model
    params, consts = {}, {}
    params["enc_pos"] = b.tensor("enc_pos", (cfg.encoder_seq, d), "normal", fan_in=d)
    params["enc"], ce = stack_layers(b.sub("enc"),
                                     lambda bb: _init_enc_block(bb, cfg),
                                     cfg.encoder_layers, "e")
    if ce:
        consts["enc"] = ce
    params["enc_ln"] = _ln(b, "enc_ln", d)
    params["embed"] = b.tensor("embed", (cfg.padded_vocab, d), "normal", fan_in=d)
    params["dec_pos"] = b.tensor("dec_pos", (cfg.max_seq_len, d), "normal", fan_in=d)
    params["dec"], cd = stack_layers(b.sub("dec"),
                                     lambda bb: _init_dec_block(bb, cfg),
                                     cfg.n_layers, "d")
    if cd:
        consts["dec"] = cd
    params["dec_ln"] = _ln(b, "dec_ln", d)
    return params, consts


def encode(cfg: ModelConfig, params, consts, frames):
    """frames: (B, encoder_seq, d_model) stub embeddings → encoder output."""
    h = frames + params["enc_pos"][None].astype(frames.dtype)

    def body(x, layer):
        p, c = layer
        a, _ = attention.apply_attention(cfg, p["attn"], c.get("attn", {}),
                                         _apply_ln(p["ln1"], x, cfg.norm_eps),
                                         causal=False)
        x = x + a
        m = mlp.apply_mlp(cfg, p["mlp"], c.get("mlp", {}),
                          _apply_ln(p["ln2"], x, cfg.norm_eps), act="gelu")
        return x + m, None

    h, _ = jax.lax.scan(body, h, (params["enc"], consts.get("enc", {})))
    return _apply_ln(params["enc_ln"], h, cfg.norm_eps)


def _dec_block(cfg, p, c, x, enc_out, *, cache=None, cache_index=None,
               pos_offset=0):
    a, new_kv = attention.apply_attention(
        cfg, p["attn"], c.get("attn", {}), _apply_ln(p["ln1"], x, cfg.norm_eps),
        causal=True, cache=cache, cache_index=cache_index, pos_offset=pos_offset)
    x = x + a
    xa, _ = attention.apply_attention(
        cfg, p["xattn"], c.get("xattn", {}), _apply_ln(p["ln_x"], x, cfg.norm_eps),
        causal=False, kv_source=enc_out)
    x = x + xa
    m = mlp.apply_mlp(cfg, p["mlp"], c.get("mlp", {}),
                      _apply_ln(p["ln2"], x, cfg.norm_eps), act="gelu")
    return x + m, new_kv


def apply_whisper(cfg: ModelConfig, params, consts, tokens, frames, *,
                  remat: str = "none"):
    """Teacher-forced training forward: (logits (B, S, V), aux=0)."""
    enc_out = encode(cfg, params, consts, frames)
    s = tokens.shape[1]
    h = jnp.take(params["embed"], tokens, axis=0) \
        + params["dec_pos"][:s][None].astype(cfg.dtype)

    def body(x, layer):
        p, c = layer
        x, _ = _dec_block(cfg, p, c, x, enc_out)
        return x, None

    if remat != "none":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, (params["dec"], consts.get("dec", {})))
    h = _apply_ln(params["dec_ln"], h, cfg.norm_eps)
    return h @ params["embed"].T.astype(h.dtype), jnp.float32(0.0)


def init_whisper_cache(cfg: ModelConfig, batch: int, max_len: int,
                       abstract: bool = False):
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    mk = (lambda s: jax.ShapeDtypeStruct(s, dt)) if abstract else \
         (lambda s: jnp.zeros(s, dt))
    L = cfg.n_layers
    return {
        "self": {"k": mk((L, batch, max_len, cfg.n_kv_heads, hd)),
                 "v": mk((L, batch, max_len, cfg.n_kv_heads, hd))},
        "enc_out": mk((batch, cfg.encoder_seq, cfg.d_model)),
    }


def whisper_prefill_cache(cfg, params, consts, frames, batch, max_len):
    """Run the encoder once and seed the decode cache."""
    cache = init_whisper_cache(cfg, batch, max_len)
    cache["enc_out"] = encode(cfg, params, consts, frames).astype(cfg.dtype)
    return cache


def whisper_decode_step(cfg: ModelConfig, params, consts, tokens, cache, index):
    h = jnp.take(params["embed"], tokens, axis=0)
    pos = jax.lax.dynamic_slice_in_dim(params["dec_pos"], index, 1, axis=0)
    h = h + pos[None].astype(h.dtype)
    enc_out = cache["enc_out"]

    def body(x, layer):
        p, c, k, v = layer
        x, new_kv = _dec_block(cfg, p, c, x, enc_out, cache={"k": k, "v": v},
                               cache_index=index)
        return x, new_kv

    h, new_kv = jax.lax.scan(body, h, (params["dec"], consts.get("dec", {}),
                                       cache["self"]["k"], cache["self"]["v"]))
    h = _apply_ln(params["dec_ln"], h, cfg.norm_eps)
    new_cache = {"self": new_kv, "enc_out": enc_out}
    return h @ params["embed"].T.astype(h.dtype), new_cache
