"""Shared model building blocks + the parameter Builder.

The Builder abstracts "concrete init" (real arrays, numpy support sampling)
vs "abstract init" (ShapeDtypeStruct, zero allocation) so every model's
parameter structure is written exactly once and the dry-run can build 405B
models on a laptop (DESIGN §6).
"""
from __future__ import annotations

import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParamConfig
from repro.core import lowrank, relora, sltrain


def _name_hash(path: str) -> int:
    return zlib.crc32(path.encode()) & 0x7FFFFFFF


class Builder:
    """Creates parameter/const pytrees; concrete iff key is not None."""

    def __init__(self, cfg: ModelConfig, key=None, path: str = "", seed: int = 0):
        self.cfg = cfg
        self.key = key
        self.path = path
        self.seed = seed
        self.dtype = jnp.dtype(cfg.dtype)

    @property
    def concrete(self) -> bool:
        return self.key is not None

    def sub(self, name: str) -> "Builder":
        k = None
        if self.key is not None:
            k = jax.random.fold_in(self.key, _name_hash(name))
        return Builder(self.cfg, k, f"{self.path}/{name}", self.seed)

    # -- raw tensors --------------------------------------------------------
    def tensor(self, name: str, shape: Tuple[int, ...], init: str = "normal",
               fan_in: Optional[int] = None, dtype=None):
        dtype = dtype or self.dtype
        if not self.concrete:
            return jax.ShapeDtypeStruct(shape, dtype)
        k = jax.random.fold_in(self.key, _name_hash(name))
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        fan = fan_in if fan_in is not None else (shape[0] if len(shape) >= 2 else shape[-1])
        if init == "normal":
            std = 1.0 / np.sqrt(fan)
            return (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)
        if init == "kaiming":
            lim = np.sqrt(6.0 / fan)
            return jax.random.uniform(k, shape, jnp.float32, -lim, lim).astype(dtype)
        raise ValueError(init)

    # -- linear factory (the paper's technique plugs in here) ---------------
    def linear(self, name: str, d_in: int, d_out: int, adapted: bool = True,
               bias: bool = False):
        """Returns (params, consts). ``adapted=False`` forces dense (embeds,
        routers, norms-adjacent projections the paper keeps full-rank)."""
        pc: ParamConfig = self.cfg.param
        b = self.sub(name)
        consts: dict = {}
        # per-matrix effective rank: global rank capped at half the min dim
        # (MoE expert / gate matrices are much smaller than attention ones)
        r = max(4, min(pc.rank, min(d_in, d_out) // 2))
        if (not adapted) or pc.mode == "dense":
            params = {"w": b.tensor("w", (d_in, d_out), "normal", fan_in=d_in)}
        elif pc.mode == "lowrank":
            if b.concrete:
                params = lowrank.init_params(b.key, d_in, d_out, r, b.dtype)
            else:
                params = lowrank.abstract_params(d_in, d_out, r, b.dtype)
        elif pc.mode == "relora":
            if b.concrete:
                params = relora.init_params(b.key, d_in, d_out, r, b.dtype)
            else:
                params = relora.abstract_params(d_in, d_out, r, b.dtype)
        elif pc.mode == "sltrain":
            # exec_mode="fused" adds the tile-CSR index consts; their
            # shapes are deterministic (support.tile_cap), so the abstract
            # twin matches and stack_layers can stack them across layers
            if b.concrete:
                params, consts = sltrain.init_params(
                    b.key, d_in, d_out, r, pc.delta, b.dtype,
                    pc.support_kind, seed=self.seed ^ _name_hash(b.path),
                    exec_mode=pc.exec_mode)
            else:
                params, consts = sltrain.abstract_params(
                    d_in, d_out, r, pc.delta, b.dtype, pc.support_kind,
                    exec_mode=pc.exec_mode)
        else:
            raise ValueError(pc.mode)
        if bias:
            params["bias"] = b.tensor("bias", (d_out,), "zeros")
        return params, consts


def apply_linear(cfg: ModelConfig, params, consts, x, adapted: bool = True):
    pc = cfg.param
    if (not adapted) or pc.mode == "dense" or "w" in params:
        y = x @ params["w"]
    else:
        # per-matrix scale alpha/r_eff (r_eff capped at init, see Builder.linear)
        scale = pc.alpha / params["B"].shape[-1]
        if pc.mode == "lowrank":
            y = lowrank.lr_matmul(x, params, scale)
        elif pc.mode == "relora":
            y = relora.rl_matmul(x, params, scale)
        elif pc.mode == "sltrain":
            y = sltrain.sl_matmul(x, params, consts, scale, pc.exec_mode)
        else:
            raise ValueError(pc.mode)
    if "bias" in params:
        y = y + params["bias"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Normalization / activations / rope
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# Ambient-mesh sharding constraints (§Perf: SP / attention layouts)
#
# Owned by repro.dist.sharding; re-exported here because every model file
# already imports them from common.
# ---------------------------------------------------------------------------

from repro.dist.sharding import ambient_mesh, constrain  # noqa: E402,F401


def rms_norm(x, weight, eps: float = 1e-6, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    w = weight.astype(jnp.float32)
    if plus_one:                      # gemma convention: scale = (1 + w)
        w = 1.0 + w
    return (n * w).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    n = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (n * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def softcap(x, cap: float):
    if cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


def rope(x, pos, theta: float = 10000.0):
    """Rotary embedding. x: (..., seq, heads, head_dim); pos: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (np.log(theta) / half))
    ang = pos[..., :, None].astype(jnp.float32) * freqs[None, :]   # (..., s, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# Abstract stacking helper
# ---------------------------------------------------------------------------

def remat_wrap(fn, remat: str):
    """Apply the configured remat policy ("none" | "full" |
    "dots_saveable") to a scan-step/segment function. Single owner of the
    policy-name mapping: the train forward (lm.apply_lm), the
    boundary-saving forward and the per-layer backward sweep
    (train/perlayer.py) must recompute under the SAME policy."""
    if remat == "none":
        return fn
    policy = None if remat == "full" else \
        jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(fn, policy=policy)


def stack_layers(builder: Builder, fn, n: int, name: str = "layer"):
    """Stack per-layer (params, consts) along a new leading axis.

    Concrete: calls fn once per layer (distinct keys/supports) and stacks.
    Abstract: calls fn once and prepends n to every leaf shape (O(1))."""
    if n == 0:
        return {}, {}
    if builder.concrete:
        ps, cs = zip(*(fn(builder.sub(f"{name}{i}")) for i in range(n)))
        stackf = lambda *xs: jnp.stack(xs)
        params = jax.tree.map(stackf, *ps) if ps[0] else {}
        consts = jax.tree.map(stackf, *cs) if cs[0] else {}
        return params, consts
    p, c = fn(builder.sub(f"{name}0"))
    add = lambda t: jax.ShapeDtypeStruct((n,) + tuple(t.shape), t.dtype)
    return jax.tree.map(add, p), jax.tree.map(add, c)
