"""Mamba2 (SSD) mixer + the zamba2-style hybrid model.

The SSD scan is the *chunked matmul* formulation (Mamba-2 paper §6) — intra-
chunk work is dense einsums (MXU-friendly on TPU, the hardware adaptation
DESIGN §3 calls for) and the inter-chunk recurrence is a tiny lax.scan over
S/chunk states.

zamba2 hybrid: runs of `hybrid_attn_every` mamba blocks followed by an
invocation of ONE weight-shared attention+MLP block with per-invocation
low-rank adapters (that is zamba2's actual design — pleasantly, the same
low-rank idea the paper builds on), consuming concat(hidden, embeddings).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention, mlp
from repro.models.common import Builder, apply_linear, rms_norm, silu, stack_layers


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def _segsum(x):
    """x: (..., l) → (..., l, l) with out[i,j] = sum_{k=j+1..i} x_k, -inf above diag."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), dtype=bool))
    return jnp.where(mask, ss, -jnp.inf)


def ssd(x, a_log, B, C, chunk: int):
    """Chunked state-space dual scan.

    x: (b, s, h, p) — inputs (already gated by dt); a_log: (b, s, h) — log
    decay per step (dt * A, ≤ 0); B, C: (b, s, n) — shared across heads
    (single group). Returns y: (b, s, h, p) and final state (b, h, p, n)."""
    b, s_orig, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s_orig)
    pad = (-s_orig) % chunk
    if pad:
        # zero x/B/C contribute nothing; a_log=0 → decay 1 (harmless)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    ac = a_log.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)      # (b,h,c,l)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    a_cum = jnp.cumsum(ac, axis=-1)                                 # (b,h,c,l)
    L = jnp.exp(_segsum(ac))                                        # (b,h,c,l,l)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        Cc, Bc, L.astype(jnp.float32), xc.astype(jnp.float32))

    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)                 # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states,
                        xc.astype(jnp.float32))                     # (b,c,h,p,n)
    chunk_decay = jnp.exp(a_cum[..., -1])                           # (b,h,c)

    def scan_fn(carry, xs):
        st, dec = xs                                                # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                           # emit PREV state

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init, (states.transpose(1, 0, 2, 3, 4),
                        chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)              # (b,c,h,p,n)

    state_decay = jnp.exp(a_cum)                                    # (b,h,c,l)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p).astype(x.dtype)
    return y[:, :s_orig], final


def ssd_step(state, x_t, a_log_t, B_t, C_t):
    """Single-token recurrence. state: (b,h,p,n); x_t: (b,h,p);
    a_log_t: (b,h); B_t, C_t: (b,n)."""
    dec = jnp.exp(a_log_t)[..., None, None]
    upd = jnp.einsum("bhp,bn->bhpn", x_t.astype(jnp.float32),
                     B_t.astype(jnp.float32))
    new = state * dec + upd
    y = jnp.einsum("bhpn,bn->bhp", new, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    h = d_inner // sc.head_dim
    return d_inner, h, sc.state_dim, sc.conv_width


def init_mamba_block(b: Builder, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, h, n, cw = _dims(cfg)
    conv_dim = d_inner + 2 * n
    params, consts = {}, {}
    params["ln"] = b.tensor("ln", (d,), "ones")
    p, c = b.linear("in_proj", d, 2 * d_inner + 2 * n + h)
    params["in_proj"] = p
    if c:
        consts["in_proj"] = c
    params["conv_w"] = b.tensor("conv_w", (cw, conv_dim), "normal", fan_in=cw)
    params["conv_b"] = b.tensor("conv_b", (conv_dim,), "zeros")
    params["A_log"] = b.tensor("A_log", (h,), "ones", dtype=jnp.float32)
    params["dt_bias"] = b.tensor("dt_bias", (h,), "zeros", dtype=jnp.float32)
    params["D"] = b.tensor("D", (h,), "ones", dtype=jnp.float32)
    params["out_norm"] = b.tensor("out_norm", (d_inner,), "ones")
    p, c = b.linear("out_proj", d_inner, d)
    params["out_proj"] = p
    if c:
        consts["out_proj"] = c
    return params, consts


def _conv1d(x, w, bias, state=None):
    """Causal depthwise conv. x: (b, s, c); w: (cw, c). If state (b, cw-1, c)
    is given, runs in streaming mode and returns (y, new_state)."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(cw))
    y = y + bias[None, None]
    new_state = pad[:, -(cw - 1):, :] if state is not None else None
    return y, new_state


def apply_mamba_block(cfg: ModelConfig, p, c, x, *, cache=None):
    """cache: {"conv": (b, cw-1, conv_dim), "ssm": (b, h, p, n)} for decode."""
    d_inner, h, n, cw = _dims(cfg)
    hd = cfg.ssm.head_dim
    res = x
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = apply_linear(cfg, p["in_proj"], c.get("in_proj", {}), xn)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _conv1d(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = silu(xbc)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (b,s,h)
    a = -jnp.exp(p["A_log"])                                         # (h,)
    a_log = dt * a                                                   # (b,s,h)
    xh = xs.reshape(*xs.shape[:-1], h, hd)
    xh_dt = xh.astype(jnp.float32) * dt[..., None]

    if cache is None:
        y, _ = ssd(xh_dt.astype(x.dtype), a_log, B, C, cfg.ssm.chunk)
        new_cache = None
    else:
        y_t, new_ssm = ssd_step(cache["ssm"], xh_dt[:, 0], a_log[:, 0],
                                B[:, 0], C[:, 0])
        y = y_t[:, None]
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": new_ssm}
    y = y + (p["D"][None, None, :, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(*y.shape[:-2], d_inner)
    y = rms_norm(y * silu(z).astype(y.dtype), p["out_norm"], cfg.norm_eps)
    out = apply_linear(cfg, p["out_proj"], c.get("out_proj", {}), y.astype(x.dtype))
    return res + out.astype(res.dtype), new_cache


# ---------------------------------------------------------------------------
# zamba2 hybrid model
# ---------------------------------------------------------------------------

def _hybrid_counts(cfg: ModelConfig):
    per = cfg.hybrid_attn_every
    n_super = cfg.n_layers // per
    tail = cfg.n_layers - n_super * per
    return per, n_super, tail


def init_hybrid(cfg: ModelConfig, key=None, seed: int = 0):
    b = Builder(cfg, key, seed=seed)
    per, n_super, tail = _hybrid_counts(cfg)
    params, consts = {}, {}
    params["embed"] = b.tensor("embed", (cfg.padded_vocab, cfg.d_model),
                               "normal", fan_in=cfg.d_model)

    def super_block(bb: Builder):
        ps, cs = stack_layers(bb, lambda b2: init_mamba_block(b2, cfg), per, "m")
        out_p = {"mamba": ps}
        out_c = {"mamba": cs} if cs else {}
        # per-invocation low-rank adapter on the shared block input proj
        r = max(8, cfg.param.rank // 2)
        out_p["adapter"] = {
            "B": bb.tensor("adB", (2 * cfg.d_model, r), "zeros"),
            "A": bb.tensor("adA", (r, cfg.d_model), "kaiming", fan_in=2 * cfg.d_model),
        }
        return out_p, out_c

    params["supers"], cs = stack_layers(b.sub("supers"), super_block, n_super, "s")
    if cs:
        consts["supers"] = cs
    if tail:
        params["tail"], ct = stack_layers(
            b.sub("tail"), lambda b2: init_mamba_block(b2, cfg), tail, "m")
        if ct:
            consts["tail"] = ct

    # ONE shared attention+MLP block (weights reused at every invocation)
    sb = b.sub("shared_attn")
    shared, shared_c = {}, {}
    p, c = sb.linear("in_proj", 2 * cfg.d_model, cfg.d_model)
    shared["in_proj"] = p
    if c:
        shared_c["in_proj"] = c
    shared["ln"] = sb.tensor("ln", (2 * cfg.d_model,), "ones")
    p, c = attention.init_attention(sb.sub("attn"), cfg)
    shared["attn"] = p
    if c:
        shared_c["attn"] = c
    shared["ln_mlp"] = sb.tensor("ln_mlp", (cfg.d_model,), "ones")
    p, c = mlp.init_mlp(sb.sub("mlp"), cfg)
    shared["mlp"] = p
    if c:
        shared_c["mlp"] = c
    params["shared"] = shared
    if shared_c:
        consts["shared"] = shared_c
    params["ln_f"] = b.tensor("ln_f", (cfg.d_model,), "ones")
    if not cfg.tie_embeddings:
        params["lm_head"] = b.tensor("lm_head", (cfg.d_model, cfg.padded_vocab),
                                     "normal", fan_in=cfg.d_model)
    return params, consts


def _apply_shared(cfg, shared, shared_c, adapter, x, h0, *, cache=None,
                  cache_index=None, pos_offset=0):
    cat = jnp.concatenate([x, h0], axis=-1)
    catn = rms_norm(cat, shared["ln"], cfg.norm_eps)
    inp = apply_linear(cfg, shared["in_proj"], shared_c.get("in_proj", {}), catn)
    inp = inp + ((catn @ adapter["B"]) @ adapter["A"]).astype(inp.dtype)
    a, new_cache = attention.apply_attention(
        cfg, shared["attn"], shared_c.get("attn", {}), inp, causal=True,
        cache=cache, cache_index=cache_index, pos_offset=pos_offset)
    x = x + a
    m = mlp.apply_mlp(cfg, shared["mlp"], shared_c.get("mlp", {}),
                      rms_norm(x, shared["ln_mlp"], cfg.norm_eps))
    return x + m, new_cache


def apply_hybrid(cfg: ModelConfig, params, consts, tokens, *, remat: str = "none"):
    per, n_super, tail = _hybrid_counts(cfg)
    h = jnp.take(params["embed"], tokens, axis=0)
    h0 = h

    def super_body(carry, layer):
        x = carry
        p, c = layer
        def inner(x, m_layer):
            mp, mc = m_layer
            x, _ = apply_mamba_block(cfg, mp, mc, x)
            return x, None
        x, _ = jax.lax.scan(inner, x, (p["mamba"], c.get("mamba", {})))
        x, _ = _apply_shared(cfg, params["shared"], consts.get("shared", {}),
                             p["adapter"], x, h0)
        return x, None

    if remat != "none":
        super_body = jax.checkpoint(super_body)
    h, _ = jax.lax.scan(super_body, h, (params["supers"], consts.get("supers", {})))
    if tail:
        def tail_body(x, m_layer):
            mp, mc = m_layer
            x, _ = apply_mamba_block(cfg, mp, mc, x)
            return x, None
        h, _ = jax.lax.scan(tail_body, h, (params["tail"], consts.get("tail", {})))
    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ w.astype(h.dtype), jnp.float32(0.0)


def init_hybrid_cache(cfg: ModelConfig, batch: int, max_len: int,
                      abstract: bool = False):
    d_inner, h, n, cw = _dims(cfg)
    per, n_super, tail = _hybrid_counts(cfg)
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    mk = (lambda s, d=dt: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d=dt: jnp.zeros(s, d))
    mamba = lambda lead: {"conv": mk(lead + (batch, cw - 1, d_inner + 2 * n)),
                          "ssm": mk(lead + (batch, h, cfg.ssm.head_dim, n), jnp.float32)}
    cache = {"supers": {"mamba": mamba((n_super, per)),
                        "attn": {"k": mk((n_super, batch, max_len, cfg.n_kv_heads, hd)),
                                 "v": mk((n_super, batch, max_len, cfg.n_kv_heads, hd))}}}
    if tail:
        cache["tail"] = mamba((tail,))
    return cache


def hybrid_decode_step(cfg: ModelConfig, params, consts, tokens, cache, index):
    per, n_super, tail = _hybrid_counts(cfg)
    h = jnp.take(params["embed"], tokens, axis=0)
    h0 = h

    def super_body(x, layer):
        p, c, kv = layer
        def inner(x, m_layer):
            mp, mc, mcache = m_layer
            x, ncache = apply_mamba_block(cfg, mp, mc, x, cache=mcache)
            return x, ncache
        x, new_mamba = jax.lax.scan(inner, x, (p["mamba"], c.get("mamba", {}),
                                               kv["mamba"]))
        x, new_attn = _apply_shared(cfg, params["shared"], consts.get("shared", {}),
                                    p["adapter"], x, h0, cache=kv["attn"],
                                    cache_index=index)
        return x, {"mamba": new_mamba, "attn": new_attn}

    h, new_supers = jax.lax.scan(super_body, h,
                                 (params["supers"], consts.get("supers", {}),
                                  cache["supers"]))
    new_cache = {"supers": new_supers}
    if tail:
        def tail_body(x, m_layer):
            mp, mc, mcache = m_layer
            x, ncache = apply_mamba_block(cfg, mp, mc, x, cache=mcache)
            return x, ncache
        h, new_tail = jax.lax.scan(tail_body, h, (params["tail"],
                                                  consts.get("tail", {}),
                                                  cache["tail"]))
        new_cache["tail"] = new_tail
    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ w.astype(h.dtype), new_cache
