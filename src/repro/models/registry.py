"""Uniform model API: every architecture exposes init / apply / init_cache /
decode_step so the trainer, server, dry-run and tests are arch-agnostic."""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Callable, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class PerLayerApi:
    """Segmented forward the per-layer backward sweep drives
    (repro.train.perlayer): one callable per model segment, each taking
    exactly the param subtree it reads so the sweep can jax.vjp segments in
    isolation. ``forward_boundaries`` must run the SAME math as ``apply``
    (loss parity with update_mode="global" depends on it)."""
    forward_boundaries: Callable  # (cfg, params, consts, batch, remat) -> dict
    embed: Callable               # (cfg, {"embed": leaf}, tokens, patches) -> h0
    period: Callable              # (cfg, p_period, c_period, x) -> (x', aux)
    dense: Callable               # (cfg, p_block, c_block, x) -> (x', aux)
    head: Callable                # (cfg, head_params, h_top) -> logits


@dataclass(frozen=True)
class ModelApi:
    init: Callable          # (cfg, key=None, seed=0) -> (params, consts)
    apply: Callable         # (cfg, params, consts, batch, remat) -> (logits, aux)
    init_cache: Callable    # (cfg, batch, max_len, abstract) -> cache
    decode_step: Callable   # (cfg, params, consts, tokens, cache, index) -> (logits, cache)
    # batched whole-prompt forward that also writes K/V; None on families
    # without one (the serve engine's paged path requires it)
    prefill_step: Optional[Callable] = None
    # segmented per-layer API; None on families without one (the
    # update_mode="per_layer" train path requires it)
    perlayer: Optional[PerLayerApi] = None


def _lm_api():
    from repro.models import lm

    def apply(cfg, params, consts, batch, remat="none"):
        return lm.apply_lm(cfg, params, consts, batch["tokens"],
                           patch_embeds=batch.get("patches"), remat=remat)

    def forward_boundaries(cfg, params, consts, batch, remat="none"):
        return lm.forward_saving_boundaries(
            cfg, params, consts, batch["tokens"],
            patch_embeds=batch.get("patches"), remat=remat)

    pl = PerLayerApi(forward_boundaries, lm.embed_apply, lm.period_apply,
                     lm.dense_apply, lm.head_apply)
    return ModelApi(lm.init_lm, apply, lm.init_cache, lm.decode_step,
                    lm.prefill_step, perlayer=pl)


def _hybrid_api():
    from repro.models import mamba2

    def apply(cfg, params, consts, batch, remat="none"):
        return mamba2.apply_hybrid(cfg, params, consts, batch["tokens"], remat=remat)

    return ModelApi(mamba2.init_hybrid, apply, mamba2.init_hybrid_cache,
                    mamba2.hybrid_decode_step)


def _xlstm_api():
    from repro.models import xlstm

    def apply(cfg, params, consts, batch, remat="none"):
        return xlstm.apply_xlstm(cfg, params, consts, batch["tokens"], remat=remat)

    return ModelApi(xlstm.init_xlstm, apply, xlstm.init_xlstm_cache,
                    xlstm.xlstm_decode_step)


def _whisper_api():
    from repro.models import whisper

    def apply(cfg, params, consts, batch, remat="none"):
        return whisper.apply_whisper(cfg, params, consts, batch["tokens"],
                                     batch["frames"], remat=remat)

    return ModelApi(whisper.init_whisper, apply, whisper.init_whisper_cache,
                    whisper.whisper_decode_step)


_FAMILY_API = {
    "llama": _lm_api, "moe": _lm_api, "gemma2": _lm_api, "vlm": _lm_api,
    "mamba_hybrid": _hybrid_api, "xlstm": _xlstm_api, "whisper": _whisper_api,
}

# arch id -> config module under repro.configs
ARCHS = (
    "qwen3_moe_235b", "deepseek_moe_16b", "yi_34b", "qwen2_5_32b", "gemma2_2b",
    "llama3_405b", "paligemma_3b", "zamba2_7b", "xlstm_350m", "whisper_large_v3",
)
PAPER_ARCHS = ("llama_60m", "llama_130m", "llama_350m", "llama_1b", "llama_7b")


def get_api(cfg: ModelConfig) -> ModelApi:
    return _FAMILY_API[cfg.family]()


def get_config(arch: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    cfg: ModelConfig = mod.CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.SMOKE


# ---------------------------------------------------------------------------
# Shape-cell applicability (skips per DESIGN §5)
# ---------------------------------------------------------------------------

_SUBQUADRATIC = {"zamba2_7b", "xlstm_350m"}


def cell_applicable(arch: str, cell_name: str) -> bool:
    if cell_name == "long_500k":
        return arch in _SUBQUADRATIC
    return True


def skip_reason(arch: str, cell_name: str) -> Optional[str]:
    if cell_applicable(arch, cell_name):
        return None
    return ("pure full-attention arch: 500k context needs sub-quadratic "
            "attention (DESIGN §5 skip note)")
