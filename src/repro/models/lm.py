"""Decoder-only LM covering the llama / qwen / yi / gemma2 / MoE / VLM
families, with scan-over-layers (stacked params → O(1) HLO in depth) and a
KV-cache decode path.

Heterogeneous layer patterns (gemma2 local/global alternation, deepseek
first-k-dense) are handled by scanning over *pattern periods*: the stacks
are shaped (L/P, P, ...) and the P intra-period blocks are unrolled with
static kinds, so the scan body stays uniform (DESIGN §6).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention, mlp
from repro.models.common import (Builder, remat_wrap, rms_norm, softcap,
                                 stack_layers)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _init_block(b: Builder, cfg: ModelConfig, use_moe: bool, d_ff_dense: int = 0):
    params, consts = {}, {}
    params["ln_attn"] = b.tensor("ln_attn", (cfg.d_model,), "zeros" if cfg.use_post_norms else "ones")
    p, c = attention.init_attention(b.sub("attn"), cfg)
    params["attn"] = p
    if c:
        consts["attn"] = c
    params["ln_mlp"] = b.tensor("ln_mlp", (cfg.d_model,), "zeros" if cfg.use_post_norms else "ones")
    if cfg.use_post_norms:
        params["ln_attn_post"] = b.tensor("ln_attn_post", (cfg.d_model,), "zeros")
        params["ln_mlp_post"] = b.tensor("ln_mlp_post", (cfg.d_model,), "zeros")
    if use_moe:
        p, c = mlp.init_moe(b.sub("moe"), cfg)
        params["moe"] = p
        if c:
            consts["moe"] = c
    else:
        p, c = mlp.init_mlp(b.sub("mlp"), cfg, d_ff=d_ff_dense or cfg.d_ff)
        params["mlp"] = p
        if c:
            consts["mlp"] = c
    return params, consts


def _apply_block(cfg: ModelConfig, p, c, x, *, window: int, cache=None,
                 cache_index=None, pos_offset=0, block_table=None,
                 prefill: bool = False):
    plus_one = cfg.family in ("gemma2", "vlm")
    act = "gelu" if cfg.family in ("gemma2", "vlm") else "silu"
    norm = lambda t, w: rms_norm(t, w, cfg.norm_eps, plus_one=plus_one)
    h = norm(x, p["ln_attn"])
    a, new_cache = attention.apply_attention(
        cfg, p["attn"], c.get("attn", {}), h, pos_offset=pos_offset,
        causal=True, window=window, cache=cache, cache_index=cache_index,
        block_table=block_table, prefill=prefill)
    if cfg.use_post_norms:
        a = norm(a, p["ln_attn_post"])
    x = x + a
    h = norm(x, p["ln_mlp"])
    aux = jnp.float32(0.0)
    if "moe" in p:
        m, aux = mlp.apply_moe(cfg, p["moe"], c.get("moe", {}), h)
    else:
        m = mlp.apply_mlp(cfg, p["mlp"], c.get("mlp", {}), h, act=act)
    if cfg.use_post_norms:
        m = norm(m, p["ln_mlp_post"])
    return x + m, new_cache, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def _pattern(cfg: ModelConfig):
    pat = cfg.attn_pattern or ("global",)
    assert cfg.n_layers % len(pat) == 0 or cfg.moe.first_k_dense, \
        f"{cfg.name}: n_layers {cfg.n_layers} not divisible by pattern {pat}"
    return pat


def init_lm(cfg: ModelConfig, key=None, seed: int = 0):
    b = Builder(cfg, key, seed=seed)
    params, consts = {}, {}
    params["embed"] = b.tensor("embed", (cfg.padded_vocab, cfg.d_model),
                               "normal", fan_in=cfg.d_model)
    use_moe = cfg.moe.n_experts > 0
    pat = _pattern(cfg)
    n_dense = cfg.moe.first_k_dense if use_moe else 0
    n_rest = cfg.n_layers - n_dense

    if n_dense:
        params["dense_layers"], cd = stack_layers(
            b.sub("dense"), lambda bb: _init_block(bb, cfg, False, cfg.moe.d_ff_dense),
            n_dense, "dl")
        if cd:
            consts["dense_layers"] = cd

    period = len(pat)
    assert n_rest % period == 0

    def init_period(bb: Builder):
        ps, cs = [], []
        for j, kind in enumerate(pat):
            p, c = _init_block(bb.sub(f"k{j}"), cfg, use_moe)
            ps.append(p)
            cs.append(c)
        return {f"k{j}": ps[j] for j in range(period)}, \
               {f"k{j}": cs[j] for j in range(period) if cs[j]}

    params["layers"], cl = stack_layers(b.sub("blocks"), init_period,
                                        n_rest // period, "p")
    if cl:
        consts["layers"] = cl
    params["ln_f"] = b.tensor("ln_f", (cfg.d_model,),
                              "zeros" if cfg.family in ("gemma2", "vlm") else "ones")
    if not cfg.tie_embeddings:
        params["lm_head"] = b.tensor("lm_head", (cfg.d_model, cfg.padded_vocab),
                                     "normal", fan_in=cfg.d_model)
    return params, consts


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_tokens(cfg, params, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family in ("gemma2", "vlm"):
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    return h


def embed_apply(cfg: ModelConfig, params, tokens, patch_embeds=None):
    """The model's input segment: token embed (+ VLM patch splice). Takes
    only the params it reads ({"embed": leaf}) so the per-layer sweep can
    jax.vjp it against exactly that subtree."""
    h = _embed_tokens(cfg, params, tokens)
    if patch_embeds is not None:
        h = jnp.concatenate([patch_embeds.astype(h.dtype),
                             h[:, patch_embeds.shape[1]:]], axis=1)
    return h


def _unembed(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ w.astype(h.dtype)
    if cfg.final_logit_softcap > 0:
        logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits


def _window_for(cfg, kind: str) -> int:
    return cfg.sliding_window if kind == "local" else 0


def _sp_constraint(cfg, h):
    """Sequence-parallel residual constraint (§Perf): shard (B, S, d) as
    P(batch_axes, "model", None) when the ambient mesh has those axes and
    the dims divide. No-op on meshes without a model axis (CPU tests).

    All-or-nothing on purpose: if either the batch or the seq dim fails
    to divide, skip the constraint entirely — a partial (seq-only) pin
    would de-shard the surrounding remat region (the §Perf it.6 lesson
    recorded in core/sltrain.py)."""
    if not cfg.seq_shard_activations:
        return h
    from repro.dist import sharding as dist_sharding
    mesh = dist_sharding.ambient_mesh()
    if mesh is None or dist_sharding.MODEL_AXIS not in mesh.axis_names:
        return h
    batch_axes = tuple(a for a in dist_sharding.BATCH_AXES
                       if a in mesh.axis_names)
    nb = dist_sharding.axis_size(mesh, batch_axes)
    nm = dist_sharding.axis_size(mesh, dist_sharding.MODEL_AXIS)
    if h.shape[0] % max(nb, 1) or h.shape[1] % nm:
        return h
    return dist_sharding.constrain(h, batch_axes,
                                   dist_sharding.MODEL_AXIS, None)


def period_apply(cfg: ModelConfig, p, c, x):
    """One scan step of the layer stack: the full attn-pattern period.
    (x, params, consts) → (x', aux). The per-layer backward sweep vjp's
    this exact function, so train forward and sweep recompute cannot
    drift."""
    pat = _pattern(cfg)
    aux = jnp.float32(0.0)
    for j, kind in enumerate(pat):
        x, _, a = _apply_block(cfg, p[f"k{j}"], c.get(f"k{j}", {}), x,
                               window=_window_for(cfg, kind))
        aux = aux + a
    return _sp_constraint(cfg, x), aux


def dense_apply(cfg: ModelConfig, p, c, x):
    """One MoE first-k-dense prefix block. (x, params, consts) → (x', aux)."""
    x, _, a = _apply_block(cfg, p, c, x, window=0)
    return x, a


def head_apply(cfg: ModelConfig, params, h):
    """Final norm + unembed. ``params`` needs only the head leaves:
    {"ln_f", "lm_head"} (untied) or {"ln_f", "embed"} (tied)."""
    h = rms_norm(h, params["ln_f"], cfg.norm_eps,
                 plus_one=cfg.family in ("gemma2", "vlm"))
    return _unembed(cfg, params, h)


def apply_lm(cfg: ModelConfig, params, consts, tokens, *, patch_embeds=None,
             remat: str = "none"):
    """tokens: (B, S[, ]) int32 → (logits (B, S, V), aux losses).

    For VLM, patch_embeds (B, n_patches, d) replace the first n_patches
    positions (the stub frontend's output, DESIGN §5)."""
    h = embed_apply(cfg, params, tokens, patch_embeds)
    aux_total = jnp.float32(0.0)

    period_body = remat_wrap(
        lambda x, layer: period_apply(cfg, layer[0], layer[1], x), remat)

    if "dense_layers" in params:
        def dense_body(x, layer):
            p, c = layer
            return dense_apply(cfg, p, c, x)
        h, aux_d = jax.lax.scan(dense_body, h,
                                (params["dense_layers"],
                                 consts.get("dense_layers", {})))
        aux_total = aux_total + aux_d.sum()

    h, aux = jax.lax.scan(period_body, h,
                          (params["layers"], consts.get("layers", {})))
    aux_total = aux_total + aux.sum()
    return head_apply(cfg, params, h), aux_total


def forward_saving_boundaries(cfg: ModelConfig, params, consts, tokens, *,
                              patch_embeds=None, remat: str = "none"):
    """The SAME forward as :func:`apply_lm` up to the final norm, but each
    scan step additionally emits its INPUT boundary activation — the
    recompute roots the per-layer backward sweep (repro.train.perlayer)
    re-runs one layer at a time from. Saved boundaries are the only
    O(n_layers) activation term; intra-layer residuals are recomputed per
    layer under the configured remat policy.

    Returns a dict:
      h0        — embed output (the first boundary),
      dense_xs  — (n_dense, B, S, d) inputs of the MoE dense prefix (or None),
      xs        — (n_periods, B, S, d) inputs of each period scan step,
      h_top     — final residual (input to the head),
      aux_dense — (n_dense,) per-block aux losses (or None),
      aux       — (n_periods,) per-period aux losses.
    """
    from repro.dist import sharding as dist_sharding
    h0 = embed_apply(cfg, params, tokens, patch_embeds)
    save = lambda x: dist_sharding.constrain_boundary(
        x, seq_sharded=cfg.seq_shard_activations)

    h = h0
    dense_xs = aux_d = None
    if "dense_layers" in params:
        def dense_body(x, layer):
            p, c = layer
            nx, a = dense_apply(cfg, p, c, x)
            return nx, (save(x), a)
        h, (dense_xs, aux_d) = jax.lax.scan(
            dense_body, h, (params["dense_layers"],
                            consts.get("dense_layers", {})))

    def period_body(x, layer):
        p, c = layer
        nx, a = period_apply(cfg, p, c, x)
        return nx, (save(x), a)
    period_body = remat_wrap(period_body, remat)

    h_top, (xs, aux) = jax.lax.scan(period_body, h,
                                    (params["layers"],
                                     consts.get("layers", {})))
    return {"h0": h0, "dense_xs": dense_xs, "xs": xs, "h_top": h_top,
            "aux_dense": aux_d, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, abstract: bool = False,
               *, paged: bool = False, block_len: int = 16, n_blocks: int = 0):
    """Contiguous KV cache (default): leaves (lead, batch, max_len, Hkv, hd).

    ``paged=True`` builds the block-paged layout instead (serve/kv.py):
    leaves are block pools (lead, n_blocks, block_len, Hkv, hd) shared by
    every decode slot through a block table; ``n_blocks`` defaults to full
    capacity (batch slots × max_len) plus the null block."""
    hd = cfg.resolved_head_dim
    pat = _pattern(cfg)
    n_periods = (cfg.n_layers - (cfg.moe.first_k_dense or 0)) // len(pat)
    dt = jnp.dtype(cfg.dtype)

    def mk(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    if paged:
        from repro.serve.kv import PagedLayout
        layout = PagedLayout.plan(batch, max_len, block_len, n_blocks)
        tail = (layout.n_blocks, layout.block_len, cfg.n_kv_heads, hd)
    else:
        tail = (batch, max_len, cfg.n_kv_heads, hd)
    kv = lambda lead: {"k": mk(lead + tail), "v": mk(lead + tail)}
    cache = {"layers": {f"k{j}": kv((n_periods,)) for j in range(len(pat))}}
    if cfg.moe.first_k_dense:
        cache["dense_layers"] = kv((cfg.moe.first_k_dense,))
    return cache


def _cached_forward(cfg: ModelConfig, params, consts, tokens, cache, index,
                    block_table, prefill: bool):
    """Shared layer-stack walk for decode_step and prefill_step — the two
    must stay in lockstep (same dense-prefix scan, same period scan, same
    final norm/unembed), so the walk exists exactly once."""
    h = _embed_tokens(cfg, params, tokens)
    pat = _pattern(cfg)
    blk = lambda x, p, c, kv, window: _apply_block(
        cfg, p, c, x, window=window, cache=kv, cache_index=index,
        block_table=block_table, prefill=prefill)

    if "dense_layers" in params:
        def dense_body(x, layer):
            p, c, kv = layer
            x, nkv, _ = blk(x, p, c, kv, 0)
            return x, nkv
        h, new_kv = jax.lax.scan(dense_body, h,
                                 (params["dense_layers"],
                                  consts.get("dense_layers", {}),
                                  cache["dense_layers"]))
        cache = {**cache, "dense_layers": new_kv}

    def period_body(x, layer):
        p, c, kv = layer
        new_kv = {}
        for j, kind in enumerate(pat):
            x, nk, _ = blk(x, p[f"k{j}"], c.get(f"k{j}", {}), kv[f"k{j}"],
                           _window_for(cfg, kind))
            new_kv[f"k{j}"] = nk
        return x, new_kv

    h, new_layers = jax.lax.scan(period_body, h,
                                 (params["layers"],
                                  consts.get("layers", {}),
                                  cache["layers"]))
    cache = {**cache, "layers": new_layers}
    h = rms_norm(h, params["ln_f"], cfg.norm_eps,
                 plus_one=cfg.family in ("gemma2", "vlm"))
    return _unembed(cfg, params, h), cache


def decode_step(cfg: ModelConfig, params, consts, tokens, cache, index,
                *, block_table=None):
    """One decode step. tokens: (B, 1) int32; index: scalar position shared
    by the batch, or a (B,) vector — each slot writes/attends at its own
    position. ``block_table`` (B, blocks_per_slot) switches the cache leaves
    to the paged-pool layout (serve/kv.py). Returns (logits, new_cache)."""
    return _cached_forward(cfg, params, consts, tokens, cache, index,
                           block_table, prefill=False)


def prefill_step(cfg: ModelConfig, params, consts, tokens, cache,
                 *, block_table=None, offsets=None):
    """Batched prefill: run the whole prompt batch (B, S) through the
    train-style chunked-attention forward ONCE, writing K/V for positions
    [0, S) into the cache as each layer computes them. Returns
    (logits (B, S, V), new_cache) — logits[s, len_s - 1] scores the first
    generated token of slot s.

    All rows start at position 0 (fresh slots). With ``block_table``, rows
    that must not be written (slots mid-decode in the same batch) are
    protected by nulling their table rows — see serve/kv.py. Without a
    block table the contiguous cache is written on EVERY row, so only call
    it when the whole batch is fresh.

    ``offsets`` (B,) int32 (paged only) switches to chunked SUFFIX
    prefill: row s's tokens sit at absolute positions offsets[s] + [0, S)
    and attend the slot's PRIOR pages in place — the shared-prefix path,
    where an admission that attached resident prefix blocks read-only
    prefills only the divergent suffix. logits[s, suffix_len_s - 1] then
    scores the first generated token."""
    index = jnp.int32(0) if offsets is None else offsets.astype(jnp.int32)
    return _cached_forward(cfg, params, consts, tokens, cache, index,
                           block_table, prefill=True)
