"""GQA attention with RoPE, sliding windows, logit softcap, QK-norm, KV cache.

Prefill/train uses a q-chunked attention (scan over query blocks, full-K
scores per block) so the score transient is O(chunk·S) not O(S²) — required
for the 32k-prefill dry-run cells to fit HBM (DESIGN §6).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import (Builder, apply_linear, constrain, rms_norm,
                                 rope, softcap)


def init_attention(b: Builder, cfg: ModelConfig, cross: bool = False):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    params, consts = {}, {}
    for name, d_out in (("wq", nh * hd), ("wk", nkv * hd), ("wv", nkv * hd)):
        p, c = b.linear(name, d, d_out, adapted=True, bias=cfg.qkv_bias)
        params[name] = p
        if c:
            consts[name] = c
    p, c = b.linear("wo", nh * hd, d, adapted=True)
    params["wo"] = p
    if c:
        consts["wo"] = c
    if cfg.qk_norm:
        params["q_norm"] = b.tensor("q_norm", (hd,), "ones")
        params["k_norm"] = b.tensor("k_norm", (hd,), "ones")
    return params, consts


def _split_heads(x, n_heads, head_dim):
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


def _attend(cfg: ModelConfig, q, k, v, q_pos, k_pos, *, causal, window,
            q_chunk: int = 1024):
    """q: (B,Sq,H,hd); k,v: (B,Sk,Hkv,hd); positions: (Sq,) or (B,Sq) for
    q_pos (per-slot decode positions), (Sk,) for k_pos."""
    bsz, sq, nh, hd = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    group = nh // nkv
    scale = (cfg.query_pre_attn_scalar or hd) ** -0.5
    qg = q.reshape(bsz, sq, nkv, group, hd)
    # SP layout (§Perf it.4): q stays sequence-sharded over "model"; k/v are
    # gathered once (the only per-layer collective); the score tensor is
    # PINNED to q-seq sharding so GSPMD never replicates it (the involuntary
    # full-rematerialization path it otherwise takes for indivisible heads).
    # Decode (sq == 1) is excluded: pinning k/v replicated would undo the
    # seq-sharded KV cache (§Perf C) and re-gather it every step.
    sp = cfg.seq_shard_activations and sq > 1
    batch = ("pod", "data")
    if sp:
        qg = constrain(qg, batch, "model", None, None, None)
        k = constrain(k, batch, None, None, None)
        v = constrain(v, batch, None, None, None)

    def block(q_blk, qpos_blk):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
        if sp:
            s = constrain(s, batch, None, None, "model", None)
        if cfg.attn_logit_softcap > 0:
            s = softcap(s, cfg.attn_logit_softcap)
        # (B,Sq) q_pos → per-slot mask (B,q,k); (Sq,) → shared (1,q,k)
        qp = qpos_blk if qpos_blk.ndim == 2 else qpos_blk[None]
        mask = jnp.ones((qp.shape[0], q_blk.shape[1], sk), dtype=bool)
        if causal:
            mask &= qp[:, :, None] >= k_pos[None, None, :]
        if window > 0:
            mask &= (qp[:, :, None] - k_pos[None, None, :]) < window
        s = jnp.where(mask[:, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        if sp:
            p = constrain(p, batch, None, None, "model", None)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p,
                       v.astype(jnp.float32)).astype(q.dtype)
        if sp:
            o = constrain(o, batch, "model", None, None, None)
        return o

    if sp or sq <= q_chunk or q_pos.ndim == 2:
        # under SP the per-shard q length is already sq/|model|; chunking
        # with lax.map would slice across the sharded dim and force gathers
        o = block(qg, q_pos)
    else:
        n_blocks = (sq + q_chunk - 1) // q_chunk
        pad = n_blocks * q_chunk - sq
        qg_p = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        pos_p = jnp.pad(q_pos, (0, pad))
        qg_b = qg_p.reshape(bsz, n_blocks, q_chunk, nkv, group, hd).swapaxes(0, 1)
        pos_b = pos_p.reshape(n_blocks, q_chunk)
        o = jax.lax.map(lambda args: block(*args), (qg_b, pos_b))
        o = o.swapaxes(0, 1).reshape(bsz, n_blocks * q_chunk, nkv, group, hd)[:, :sq]
    return o.reshape(bsz, sq, nh * hd)


def apply_attention(cfg: ModelConfig, params, consts, x, *, pos_offset=0,
                    causal: bool = True, window: int = 0,
                    cache: Optional[dict] = None, cache_index=None,
                    kv_source=None, block_table=None, prefill: bool = False):
    """Self- (or cross-, via kv_source) attention.

    cache: {"k","v"}. Contiguous layout (B, S_max, Hkv, hd): decode writes
    k/v at ``cache_index`` — a scalar (one shared write offset) or a (B,)
    vector (each slot writes at its own position) — and attends over the
    whole cache with per-slot causal masking. Paged layout (``block_table``
    (B, blocks_per_slot) given): pools are (n_blocks, block_len, Hkv, hd)
    and writes scatter through the block table; how the READ runs is
    ``cfg.attn_kernel``:

    ==========  ==========================================================
    attn_kernel paged decode read path
    ==========  ==========================================================
    "gather"    materialize the gathered (B, view_len, Hkv, hd) per-slot
                view (``kv.gather_view``; null-block rows zeroed so
                garbage cannot ride 0-weight products) and run the dense
                ``_attend`` over it — HBM traffic O(B · view_len)/layer.
    "paged"     ``kernels/ops.paged_attention``: Pallas kernel streams
                K/V blocks through VMEM with online softmax (null blocks
                and past-position entries masked in-kernel, GQA groups
                broadcast in-kernel) — traffic O(live tokens)/layer. Used
                when decoding (sq == 1) with a per-slot position vector;
                per-slot chunked prefill (sq > 1 at per-slot offsets)
                dispatches the sibling ``paged_prefill_attention`` kernel
                (causal within the chunk, prior pages attended in place);
                remaining shapes (scalar-offset prefill, cross-attn) fall
                back to "gather".
    ==========  ==========================================================

    Both paths are value-equivalent within f32 attention tolerance
    (tests/test_paged_attention.py pins the matrix); "paged" is the
    default since the parity gates baked in CI ("gather" stays
    selectable, and is the automatic fallback whenever the cache is not
    paged).

    ``prefill=True`` runs the whole prompt train-style — attention over the
    just-computed local k/v (O(Sq²), chunked), not the S_max cache — while
    still writing k/v into the cache at positions [0, Sq). Contiguous
    prefill writes every batch row, so it is only safe when ALL rows are
    fresh; the paged path nulls non-admitted rows' table entries instead
    (their writes land in the null block).

    Returns (y, new_cache)."""
    hd = cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    lin = lambda n, t: apply_linear(cfg, params[n], consts.get(n, {}), t)
    bsz, sq = x.shape[0], x.shape[1]

    q = _split_heads(lin("wq", x), nh, hd)
    kv_in = x if kv_source is None else kv_source
    k = _split_heads(lin("wk", kv_in), nkv, hd)
    v = _split_heads(lin("wv", kv_in), nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    idx = cache_index if cache_index is not None else pos_offset
    per_slot = getattr(idx, "ndim", 0) == 1          # (B,) position vector
    if per_slot:
        q_pos = idx[:, None] + jnp.arange(sq, dtype=jnp.int32)[None]  # (B,Sq)
    else:
        q_pos = jnp.arange(sq, dtype=jnp.int32) + (
            idx if cache is not None else pos_offset)
    use_rope = cfg.family not in ("whisper",) and kv_source is None
    if use_rope:
        q = rope(q, q_pos if per_slot else q_pos[None], cfg.rope_theta)

    new_cache = cache
    if cache is not None and kv_source is None:
        if use_rope:
            k = rope(k, q_pos if per_slot else q_pos[None], cfg.rope_theta)
        if block_table is not None:
            from repro.serve import kv as kv_lib
            positions = q_pos if per_slot else \
                jnp.broadcast_to(q_pos[None], (bsz, sq))
            ck = kv_lib.scatter(cache["k"], block_table, positions, k)
            cv = kv_lib.scatter(cache["v"], block_table, positions, v)
            new_cache = {"k": ck, "v": cv}
            if not prefill:
                if cfg.attn_kernel == "paged" and sq == 1 and per_slot:
                    from repro.kernels import ops as kernel_ops
                    scale = (cfg.query_pre_attn_scalar or hd) ** -0.5
                    o = kernel_ops.paged_attention(
                        q[:, 0], ck, cv, block_table, idx, scale=scale,
                        softcap=cfg.attn_logit_softcap, window=window)
                    return lin("wo", o.reshape(bsz, 1, nh * hd)), new_cache
                k = kv_lib.gather_view(ck, block_table)
                v = kv_lib.gather_view(cv, block_table)
                # zero rows gathered from the null block: the causal mask
                # makes their softmax weight exactly 0, but 0 · NaN = NaN —
                # garbage in unallocated pages must not ride the p@v matmul
                live = jnp.repeat(block_table != 0, ck.shape[1], axis=1)
                k = jnp.where(live[:, :, None, None], k, 0)
                v = jnp.where(live[:, :, None, None], v, 0)
                k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
            elif per_slot:
                # chunked (suffix) prefill: slot s's queries sit at absolute
                # positions idx[s] + [0, sq) and must attend the PRIOR pages
                # (e.g. an attached shared prefix) as well as the chunk
                # itself — local-k attention is wrong whenever idx[s] > 0.
                # The chunk's own k/v was just scattered, so both read
                # paths see it through the pools.
                if cfg.attn_kernel == "paged":
                    from repro.kernels import ops as kernel_ops
                    scale = (cfg.query_pre_attn_scalar or hd) ** -0.5
                    o = kernel_ops.paged_prefill_attention(
                        q, ck, cv, block_table, idx, scale=scale,
                        softcap=cfg.attn_logit_softcap, window=window)
                    return lin("wo", o.reshape(bsz, sq, nh * hd)), new_cache
                k = kv_lib.gather_view(ck, block_table)
                v = kv_lib.gather_view(cv, block_table)
                live = jnp.repeat(block_table != 0, ck.shape[1], axis=1)
                k = jnp.where(live[:, :, None, None], k, 0)
                v = jnp.where(live[:, :, None, None], v, 0)
                k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
            else:
                k_pos = jnp.arange(sq, dtype=jnp.int32) + idx
        elif per_slot:
            rows = jnp.arange(bsz, dtype=jnp.int32)[:, None]
            cols = idx[:, None] + jnp.arange(sq, dtype=jnp.int32)[None]
            ck = cache["k"].at[rows, cols].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[rows, cols].set(v.astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            k, v = ck, cv
            k_pos = jnp.arange(cache["k"].shape[1], dtype=jnp.int32)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
            new_cache = {"k": ck, "v": cv}
            if prefill:
                k_pos = q_pos       # attend local k/v, not the S_max cache
            else:
                k, v = ck, cv
                k_pos = jnp.arange(cache["k"].shape[1], dtype=jnp.int32)
    elif kv_source is not None:
        k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    else:
        if use_rope:
            k = rope(k, q_pos[None], cfg.rope_theta)
        k_pos = q_pos

    o = _attend(cfg, q, k, v, q_pos, k_pos, causal=causal, window=window)
    return lin("wo", o), new_cache
