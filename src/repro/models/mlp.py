"""Feed-forward blocks: SwiGLU / GeGLU / plain-GELU, and top-k MoE with
shared experts (deepseek/qwen3 style), capacity-based dispatch (EP-friendly:
expert-stacked weights shard over the model axis; DESIGN §4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Builder, apply_linear, gelu, silu


# ---------------------------------------------------------------------------
# Dense gated MLP
# ---------------------------------------------------------------------------

def init_mlp(b: Builder, cfg: ModelConfig, d_ff: int = 0, gated: bool = True):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    params, consts = {}, {}
    names = (("gate", d, f), ("up", d, f), ("down", f, d)) if gated else \
            (("up", d, f), ("down", f, d))
    for name, di, do in names:
        p, c = b.linear(name, di, do)
        params[name] = p
        if c:
            consts[name] = c
    return params, consts


def apply_mlp(cfg: ModelConfig, params, consts, x, act: str = "silu"):
    lin = lambda n, t: apply_linear(cfg, params[n], consts.get(n, {}), t)
    a = {"silu": silu, "gelu": gelu}[act]
    if "gate" in params:
        return lin("down", a(lin("gate", x)) * lin("up", x))
    return lin("down", a(lin("up", x)))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe(b: Builder, cfg: ModelConfig):
    """Router (dense — paper keeps non-linear-layer params full-rank) +
    expert-stacked gated FFN + optional shared experts."""
    m = cfg.moe
    d, fe = cfg.d_model, m.d_ff_expert
    params, consts = {}, {}
    params["router"], _ = b.linear("router", d, m.n_experts, adapted=False)

    def expert(be: Builder):
        return init_mlp(be, cfg, d_ff=fe, gated=True)

    from repro.models.common import stack_layers
    params["experts"], cexp = stack_layers(b, expert, m.n_experts, "expert")
    if cexp:
        consts["experts"] = cexp
    if m.n_shared_experts:
        params["shared"], csh = init_mlp(
            b.sub("shared"), cfg, d_ff=fe * m.n_shared_experts, gated=True)
        if csh:
            consts["shared"] = csh
    return params, consts


def apply_moe(cfg: ModelConfig, params, consts, x, capacity_factor: float = 1.25):
    """Group-local capacity-based top-k dispatch (GShard-style, DESIGN §4).

    Tokens are split into G = cfg.moe_groups groups aligned with the batch
    sharding, routing/cumsum/gather are all GROUP-LOCAL (no cross-shard token
    traffic), expert compute is sharded over the model axis (EP), and the
    combine emits per-expert partials that GSPMD resolves with one
    all-reduce over the model axis. Overflowing tokens are dropped
    (combine weight 0) — standard Switch semantics, shapes static."""
    m = cfg.moe
    bsz, seq, d = x.shape
    n = bsz * seq
    g = max(1, cfg.moe_groups)
    if n % g:
        g = 1
    ng = n // g
    xg = x.reshape(g, ng, d)

    logits = apply_linear(cfg, params["router"], {}, xg, adapted=False)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)      # (G,Ng,E)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)            # (G,Ng,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(max(1, capacity_factor * ng * m.top_k / m.n_experts))
    cap = min(cap, ng * m.top_k)
    # Position of each (token, k) slot in its expert's group-local queue.
    # Sort-based ranking (§Perf MoE it.2): the naive one-hot cumsum builds a
    # (N·k × E) int tensor — at qwen3 scale 4.3 TB read/written several
    # times per layer, the dominant HBM term of the whole step. Stable-sort
    # by expert id instead: O(N·k) memory, identical positions.
    nk = ng * m.top_k
    flat_e = expert_ids.reshape(g, nk)
    order = jnp.argsort(flat_e, axis=1, stable=True)            # (G, Nk)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    idx = jnp.broadcast_to(jnp.arange(nk, dtype=jnp.int32)[None], (g, nk))
    is_new = jnp.concatenate(
        [jnp.ones((g, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    group_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_new, idx, 0), axis=1)
    rank = idx - group_start                                     # pos in expert
    pos = jnp.zeros((g, nk), jnp.int32).at[
        jnp.broadcast_to(jnp.arange(g)[:, None], (g, nk)), order
    ].set(rank, mode="drop", unique_indices=True).reshape(g, ng, m.top_k)
    keep = pos < cap
    gate_vals = jnp.where(keep, gate_vals, 0.0)
    slot = jnp.where(keep, pos, cap)                                   # cap = trash

    token_ids = jnp.broadcast_to(jnp.arange(ng, dtype=jnp.int32)[None, :, None],
                                 (g, ng, m.top_k))
    g_iota = jnp.broadcast_to(jnp.arange(g)[:, None], (g, ng * m.top_k))
    gather_idx = jnp.full((g, m.n_experts, cap + 1), ng, dtype=jnp.int32)
    gather_idx = gather_idx.at[
        g_iota, expert_ids.reshape(g, -1), slot.reshape(g, -1)].set(
        token_ids.reshape(g, -1), mode="drop")
    gather_idx = gather_idx[:, :, :cap]                                # (G,E,cap)
    xg_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    xe = jnp.take_along_axis(xg_pad[:, None], gather_idx[..., None],
                             axis=2)                                   # (G,E,cap,d)
    # NOTE (§Perf MoE it.3, REFUTED): pinning xe/ye to EP×data sharding here
    # forces reshard storms against the seq-sharded gather source — measured
    # t_m 88→122 s, t_x 40→154 s. XLA's replicated-but-local dispatch wins;
    # left unpinned deliberately.

    # expert compute (vmapped over E; sharded over model axis = EP)
    xe_t = xe.transpose(1, 0, 2, 3).reshape(m.n_experts, g * cap, d)
    if "experts" in consts:
        ye_t = jax.vmap(lambda p, c, xi: apply_mlp(cfg, p, c, xi, act="silu"))(
            params["experts"], consts["experts"], xe_t)
    else:
        ye_t = jax.vmap(lambda p, xi: apply_mlp(cfg, p, {}, xi, act="silu"))(
            params["experts"], xe_t)
    ye = ye_t.reshape(m.n_experts, g, cap, d).transpose(1, 0, 2, 3)    # (G,E,cap,d)

    # combine weights per slot
    w_slot = jnp.zeros((g, m.n_experts, cap + 1), jnp.float32)
    w_slot = w_slot.at[g_iota, expert_ids.reshape(g, -1),
                       slot.reshape(g, -1)].set(
        gate_vals.reshape(g, -1).astype(jnp.float32), mode="drop")
    ye = ye.astype(jnp.float32) * w_slot[:, :, :cap, None]

    # scatter back (per-expert partials -> all-reduce over model by GSPMD)
    yf = jnp.zeros((g, ng + 1, d), jnp.float32)
    e_iota = jnp.broadcast_to(jnp.arange(g)[:, None, None],
                              (g, m.n_experts, cap))
    yf = yf.at[e_iota, gather_idx].add(ye, mode="drop")
    y = yf[:, :ng].astype(x.dtype)

    if m.n_shared_experts:
        y = y + apply_mlp(cfg, params["shared"], consts.get("shared", {}), xg)

    # load-balancing aux loss (Switch): E * sum(frac_tokens * frac_probs).
    # scatter-add counts instead of a (N × E) one-hot (§Perf MoE it.2)
    frac_prob = probs.mean(axis=(0, 1))
    counts = jnp.zeros(m.n_experts, jnp.float32).at[
        expert_ids[..., 0].reshape(-1)].add(1.0, mode="drop")
    frac_tok = counts / (g * ng)
    aux = m.n_experts * jnp.sum(frac_prob * frac_tok)
    return y.reshape(bsz, seq, d), aux
