"""xLSTM: mLSTM (matrix-memory, chunkwise-parallel) + sLSTM (scalar-memory,
recurrent) blocks at ratio m:s = `xlstm_m_per_s` : 1.

mLSTM is trained in a chunked linear-attention form (same segsum machinery
as the SSD kernel — dense intra-chunk einsums for the MXU, lax.scan over
chunk states), with the canonical |n·q| ≥ 1 normalizer realized by
augmenting the value vectors with the gate channel. Gating uses the
stabilized sigmoid variant (log-space decays); noted in DESIGN §5.

sLSTM is inherently sequential → lax.scan over time with exponential-gating
stabilizer state m.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import Builder, apply_linear, rms_norm, silu, stack_layers
from repro.models.mamba2 import _segsum


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel
# ---------------------------------------------------------------------------

def mlstm_chunked(q, k, v, i_gate, f_gate, chunk: int):
    """q,k,v: (b,s,h,p); i_gate,f_gate: (b,s,h) raw logits.
    Returns y: (b,s,h,p) and final (C, n) state: (b,h,p,p+1)."""
    b, s_orig, h, p = q.shape
    a_log = jax.nn.log_sigmoid(f_gate)                 # per-step log decay
    i_val = jax.nn.sigmoid(i_gate)
    # augment values with the gate channel → the normalizer n rides along
    ones = jnp.ones_like(v[..., :1])
    v_aug = jnp.concatenate([v, ones], axis=-1) * i_val[..., None]  # (b,s,h,p+1)

    chunk = min(chunk, s_orig)
    pad = (-s_orig) % chunk
    if pad:
        p4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k = jnp.pad(q, p4), jnp.pad(k, p4)
        v_aug = jnp.pad(v_aug, p4)
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, h, p)
    kc = k.reshape(b, nc, chunk, h, p)
    vc = v_aug.reshape(b, nc, chunk, h, p + 1).astype(jnp.float32)
    ac = a_log.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)       # (b,h,c,l)

    a_cum = jnp.cumsum(ac, axis=-1)
    L = jnp.exp(_segsum(ac)).astype(jnp.float32)                    # (b,h,c,l,l)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        qc.astype(jnp.float32), kc.astype(jnp.float32), L, vc)

    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchnp", kc.astype(jnp.float32),
                        decay_states, vc)                           # (b,c,h,p,p+1)
    chunk_decay = jnp.exp(a_cum[..., -1])

    def scan_fn(carry, xs):
        st, dec = xs
        new = carry * dec[..., None, None] + st
        return new, carry

    init = jnp.zeros((b, h, p, p + 1), jnp.float32)
    final, prev = jax.lax.scan(scan_fn, init,
                               (states.transpose(1, 0, 2, 3, 4),
                                chunk_decay.transpose(2, 0, 1)))
    prev = prev.transpose(1, 0, 2, 3, 4)
    y_off = jnp.einsum("bclhn,bchnp,bhcl->bclhp", qc.astype(jnp.float32),
                       prev, jnp.exp(a_cum))
    y_full = (y_diag + y_off).reshape(b, s, h, p + 1)[:, :s_orig]
    num, den = y_full[..., :p], y_full[..., p]
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return y.astype(q.dtype), final


def mlstm_step(state, q_t, k_t, v_t, i_t, f_t):
    """state: (b,h,p,p+1); q/k/v_t: (b,h,p); gates: (b,h)."""
    dec = jnp.exp(jax.nn.log_sigmoid(f_t))[..., None, None]
    ival = jax.nn.sigmoid(i_t)[..., None]
    v_aug = jnp.concatenate([v_t, jnp.ones_like(v_t[..., :1])], -1) * ival
    upd = jnp.einsum("bhn,bhp->bhnp", k_t.astype(jnp.float32),
                     v_aug.astype(jnp.float32))
    new = state * dec + upd
    y_full = jnp.einsum("bhn,bhnp->bhp", q_t.astype(jnp.float32), new)
    num, den = y_full[..., :-1], y_full[..., -1]
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return y.astype(q_t.dtype), new


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def init_mlstm_block(b: Builder, cfg: ModelConfig):
    d = cfg.d_model
    d_inner = 2 * d
    h = cfg.n_heads
    params, consts = {}, {}
    params["ln"] = b.tensor("ln", (d,), "ones")
    for name, di, do in (("up", d, 2 * d_inner), ("qkv", d_inner, 3 * d_inner),
                         ("down", d_inner, d)):
        p, c = b.linear(name, di, do)
        params[name] = p
        if c:
            consts[name] = c
    params["gates"] = {"w": b.tensor("gates_w", (d_inner, 2 * h), "normal",
                                     fan_in=d_inner),
                       "b": b.tensor("gates_b", (2 * h,), "zeros",
                                     dtype=jnp.float32)}
    params["out_norm"] = b.tensor("out_norm", (d_inner,), "ones")
    return params, consts


def apply_mlstm_block(cfg: ModelConfig, p, c, x, *, cache=None):
    d = cfg.d_model
    d_inner = 2 * d
    h = cfg.n_heads
    hd = d_inner // h
    res = x
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    up = apply_linear(cfg, p["up"], c.get("up", {}), xn)
    xm, z = jnp.split(up, 2, axis=-1)
    qkv = apply_linear(cfg, p["qkv"], c.get("qkv", {}), xm)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    split = lambda t: t.reshape(*t.shape[:-1], h, hd)
    q, k, v = split(q), split(k / np.sqrt(hd)), split(v)
    gates = (xm @ p["gates"]["w"].astype(xm.dtype)).astype(jnp.float32) \
        + p["gates"]["b"]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)                    # (b,s,h)
    if cache is None:
        y, _ = mlstm_chunked(q, k, v, i_gate, f_gate, cfg.ssm.chunk)
        new_cache = None
    else:
        y_t, new_state = mlstm_step(cache["C"], q[:, 0], k[:, 0], v[:, 0],
                                    i_gate[:, 0], f_gate[:, 0])
        y = y_t[:, None]
        new_cache = {"C": new_state}
    y = y.reshape(*y.shape[:-2], d_inner)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps) * silu(z)
    return res + apply_linear(cfg, p["down"], c.get("down", {}), y), new_cache


def init_slstm_block(b: Builder, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    params, consts = {}, {}
    params["ln"] = b.tensor("ln", (d,), "ones")
    p, c = b.linear("wx", d, 4 * d)          # i, f, z, o pre-activations
    params["wx"] = p
    if c:
        consts["wx"] = c
    # block-diagonal recurrent weights, (4, h, hd, hd)
    params["R"] = b.tensor("R", (4, h, hd, hd), "normal", fan_in=hd)
    params["bias"] = b.tensor("bias", (4 * d,), "zeros", dtype=jnp.float32)
    # post-FFN (gated, factor 4/3 rounded to multiple of 64)
    f = ((int(d * 4 / 3) + 63) // 64) * 64
    for name, di, do in (("gate", d, f), ("up", d, f), ("down", f, d)):
        p, c = b.linear(f"ffn_{name}", di, do)
        params[f"ffn_{name}"] = p
        if c:
            consts[f"ffn_{name}"] = c
    params["ln_ffn"] = b.tensor("ln_ffn", (d,), "ones")
    return params, consts


def _slstm_scan(cfg, p, xg, state):
    """xg: (b, s, 4d) pre-activations; state: dict h,c,n,m of (b, heads, hd)."""
    h_heads = cfg.n_heads
    d = cfg.d_model
    hd = d // h_heads
    R = p["R"].astype(jnp.float32)

    def step(st, x_t):
        hp = st["h"]                                        # (b, h, hd)
        rec = jnp.einsum("bhd,khde->kbhe", hp, R)           # (4, b, h, hd)
        x4 = x_t.reshape(-1, 4, h_heads, hd).transpose(1, 0, 2, 3)
        it, ft, zt, ot = (x4 + rec).astype(jnp.float32)
        m_new = jnp.maximum(ft + st["m"], it)
        i = jnp.exp(it - m_new)
        f = jnp.exp(ft + st["m"] - m_new)
        c_new = f * st["c"] + i * jnp.tanh(zt)
        n_new = f * st["n"] + i
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
        return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}, h_new

    xg_t = xg.astype(jnp.float32).swapaxes(0, 1)            # (s, b, 4d)
    state, ys = jax.lax.scan(step, state, xg_t)
    return ys.swapaxes(0, 1), state                         # (b, s, h, hd)


def slstm_init_state(cfg, batch, abstract=False):
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    mk = (lambda s: jax.ShapeDtypeStruct(s, jnp.float32)) if abstract else \
         (lambda s: jnp.zeros(s, jnp.float32))
    return {k: mk((batch, h, hd)) for k in ("h", "c", "n", "m")}


def apply_slstm_block(cfg: ModelConfig, p, c, x, *, cache=None):
    res = x
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    xg = apply_linear(cfg, p["wx"], c.get("wx", {}), xn).astype(jnp.float32) \
        + p["bias"]
    state = cache["s"] if cache is not None else \
        slstm_init_state(cfg, x.shape[0])
    ys, new_state = _slstm_scan(cfg, p, xg, state)
    y = ys.reshape(*x.shape).astype(x.dtype)
    x = res + y
    hn = rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    gate = apply_linear(cfg, p["ffn_gate"], c.get("ffn_gate", {}), hn)
    up = apply_linear(cfg, p["ffn_up"], c.get("ffn_up", {}), hn)
    down = apply_linear(cfg, p["ffn_down"], c.get("ffn_down", {}), silu(gate) * up)
    new_cache = {"s": new_state} if cache is not None else None
    return x + down, new_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def _xlstm_counts(cfg: ModelConfig):
    per = cfg.xlstm_m_per_s + 1
    assert cfg.n_layers % per == 0
    return per, cfg.n_layers // per


def init_xlstm(cfg: ModelConfig, key=None, seed: int = 0):
    b = Builder(cfg, key, seed=seed)
    per, n_super = _xlstm_counts(cfg)
    params, consts = {}, {}
    params["embed"] = b.tensor("embed", (cfg.padded_vocab, cfg.d_model),
                               "normal", fan_in=cfg.d_model)

    def super_block(bb: Builder):
        mp, mc = stack_layers(bb, lambda b2: init_mlstm_block(b2, cfg),
                              cfg.xlstm_m_per_s, "m")
        sp, sc = init_slstm_block(bb.sub("s"), cfg)
        out_p = {"mlstm": mp, "slstm": sp}
        out_c = {}
        if mc:
            out_c["mlstm"] = mc
        if sc:
            out_c["slstm"] = sc
        return out_p, out_c

    params["supers"], cs = stack_layers(b.sub("supers"), super_block, n_super, "sb")
    if cs:
        consts["supers"] = cs
    params["ln_f"] = b.tensor("ln_f", (cfg.d_model,), "ones")
    params["lm_head"] = b.tensor("lm_head", (cfg.d_model, cfg.padded_vocab),
                                 "normal", fan_in=cfg.d_model)
    return params, consts


def apply_xlstm(cfg: ModelConfig, params, consts, tokens, *, remat: str = "none"):
    h = jnp.take(params["embed"], tokens, axis=0)

    def super_body(x, layer):
        p, c = layer
        def inner(x, m_layer):
            mp, mc = m_layer
            x, _ = apply_mlstm_block(cfg, mp, mc, x)
            return x, None
        x, _ = jax.lax.scan(inner, x, (p["mlstm"], c.get("mlstm", {})))
        x, _ = apply_slstm_block(cfg, p["slstm"], c.get("slstm", {}), x)
        return x, None

    if remat != "none":
        super_body = jax.checkpoint(super_body)
    h, _ = jax.lax.scan(super_body, h, (params["supers"], consts.get("supers", {})))
    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    return h @ params["lm_head"].astype(h.dtype), jnp.float32(0.0)


def init_xlstm_cache(cfg: ModelConfig, batch: int, max_len: int,
                     abstract: bool = False):
    per, n_super = _xlstm_counts(cfg)
    d_inner = 2 * cfg.d_model
    h = cfg.n_heads
    hd = d_inner // h
    mk = (lambda s: jax.ShapeDtypeStruct(s, jnp.float32)) if abstract else \
         (lambda s: jnp.zeros(s, jnp.float32))
    slstm = jax.tree.map(lambda t: mk((n_super,) + t.shape),
                         slstm_init_state(cfg, batch, abstract=True))
    return {"supers": {
        "mlstm": {"C": mk((n_super, cfg.xlstm_m_per_s, batch, h, hd, hd + 1))},
        "slstm": {"s": slstm},
    }}


def xlstm_decode_step(cfg: ModelConfig, params, consts, tokens, cache, index):
    h = jnp.take(params["embed"], tokens, axis=0)

    def super_body(x, layer):
        p, c, kv = layer
        def inner(x, m_layer):
            mp, mc, mcache = m_layer
            x, ncache = apply_mlstm_block(cfg, mp, mc, x, cache=mcache)
            return x, ncache
        x, new_m = jax.lax.scan(inner, x, (p["mlstm"], c.get("mlstm", {}),
                                           kv["mlstm"]))
        x, new_s = apply_slstm_block(cfg, p["slstm"], c.get("slstm", {}), x,
                                     cache=kv["slstm"])
        return x, {"mlstm": new_m, "slstm": new_s}

    h, new_supers = jax.lax.scan(super_body, h,
                                 (params["supers"], consts.get("supers", {}),
                                  cache["supers"]))
    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    return h @ params["lm_head"].astype(h.dtype), {"supers": new_supers}
