"""SDDMM sparse-gradient kernel: dV = (xᵀ·dy)_I  (DESIGN §3.3).

The paper's backward (eq. 2) forms the full-rank transient G = xᵀ∇z in HBM
and gathers the support entries. On TPU we fuse: each (k, n) tile of G is
computed in VMEM (accumulating over the token dimension m) and only the
*gathered* values leave the kernel — the d_in·d_out transient never touches
HBM.

Gather-as-matmul: dv[e] = G[row_e, col_e] = (P_r · G ⊙ P_c)·1, i.e. one
(E, bk)@(bk, bn) MXU matmul + a masked row-sum, where P_r/P_c are the
one-hot support matrices of the tile. Grid: (K/bk, N/bn, M/bm), m
innermost, accumulating into the (1, 1, E) output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dy_ref, r_ref, c_ref, o_ref):
    m = pl.program_id(2)

    @pl.when(m == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bk = x_ref.shape[1]
    bn = dy_ref.shape[1]
    # tile of G = x^T dy, f32 on the MXU
    g = jax.lax.dot(x_ref[...].T, dy_ref[...],
                    preferred_element_type=jnp.float32)      # (bk, bn)
    rows = r_ref[0, 0, :]
    cols = c_ref[0, 0, :]
    e = rows.shape[0]
    pr = (rows[:, None] == jax.lax.broadcasted_iota(jnp.int32, (e, bk), 1))
    pc = (cols[:, None] == jax.lax.broadcasted_iota(jnp.int32, (e, bn), 1))
    rows_of_g = jax.lax.dot(pr.astype(jnp.float32), g,
                            preferred_element_type=jnp.float32)  # (E, bn)
    dv = jnp.sum(rows_of_g * pc.astype(jnp.float32), axis=1)     # (E,)
    o_ref[...] += dv[None, None, :]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def sddmm(x, dy, rows_t, cols_t, *, bm: int = 128, bk: int = 128,
          bn: int = 128, interpret: bool = True):
    """dv tiles (K/bk, N/bn, E) f32 for the support laid out by
    ``ops.prepare_tiles``; x (M, K), dy (M, N) pre-padded to tile multiples."""
    m, k = x.shape
    n = dy.shape[1]
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n)
    nkt, nnt, e = rows_t.shape
    assert (nkt, nnt) == (k // bk, n // bn), rows_t.shape
    grid = (k // bk, n // bn, m // bm)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda kk, j, i: (i, kk)),
            pl.BlockSpec((bm, bn), lambda kk, j, i: (i, j)),
            pl.BlockSpec((1, 1, e), lambda kk, j, i: (kk, j, 0)),
            pl.BlockSpec((1, 1, e), lambda kk, j, i: (kk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, e), lambda kk, j, i: (kk, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nkt, nnt, e), jnp.float32),
        interpret=interpret,
    )(x, dy, rows_t, cols_t)
