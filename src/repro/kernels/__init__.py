"""Pallas TPU kernels (interpret=True validated on CPU; DESIGN §3):
  sl_matmul — fused (BA ⊕ S)x with tile-local VMEM densify,
  sddmm     — sparse-support gradient dV = (xᵀdy)_I without the HBM transient,
  adam8bit  — fused blockwise 8-bit Adam update,
  sparse_decode — factored decode matmul x·S (tile-CSR, S never in HBM).
ops.py holds the jit wrappers + custom-VJP linear; ref.py the jnp oracles."""
from repro.kernels import ops, ref  # noqa: F401
