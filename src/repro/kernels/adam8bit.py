"""Fused blockwise 8-bit Adam update kernel (paper §5.1 "8-bit SLTrain").

One pass over the parameter: dequantize both moments, Adam update, write
the new parameter AND requantize the moments — the f32 moments exist only
as VMEM transients, never in HBM. The XLA reference path
(``repro.optim.quant`` + ``optim.optimizers.adam8bit``) round-trips f32
moments through HBM; the fused kernel removes 8 bytes/param of HBM traffic
per step, which is the dominant memory term of the optimizer phase.

Layout: the flattened parameter is reshaped to (n_q, Q) quantization
blocks (Q = oc.q_block, default 256). Grid tiles BB quantization blocks per
kernel instance. Scalars (lr, betas, bias corrections, eps, wd) arrive as
one (8,) f32 operand broadcast to every instance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(s_ref, p_ref, g_ref, mc_ref, ms_ref, vc_ref, vs_ref,
            po_ref, mco_ref, mso_ref, vco_ref, vso_ref):
    lr, b1, b2, bc1, bc2, eps, wd, _ = [s_ref[i] for i in range(8)]
    g = g_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    # dequantize (symmetric signed m; shifted unsigned v). The v code is
    # floored at half a quantization step: a linear code zero-quantizes
    # small v within a block, and m/(sqrt(0)+eps) explodes the update
    # (bitsandbytes avoids this with a dynamic exponent code; the floor is
    # the linear-code equivalent — see test_adam8bit_converges_like_fp32).
    m = mc_ref[...].astype(jnp.float32) * ms_ref[...][:, None]
    v = jnp.maximum(vc_ref[...].astype(jnp.float32) + 128.0, 0.5) \
        * vs_ref[...][:, None]
    # Adam
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    u = u + wd * p
    po_ref[...] = (p - lr * u).astype(po_ref.dtype)
    # requantize
    ms = jnp.max(jnp.abs(m), axis=1) / 127.0
    mco_ref[...] = jnp.round(m / jnp.maximum(ms, 1e-12)[:, None]
                             ).astype(jnp.int8)
    mso_ref[...] = ms
    vs = jnp.max(v, axis=1) / 255.0
    vco_ref[...] = (jnp.round(v / jnp.maximum(vs, 1e-12)[:, None]) - 128.0
                    ).astype(jnp.int8)
    vso_ref[...] = vs


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def adam8bit_update(p, g, m_codes, m_scales, v_codes, v_scales, scalars,
                    *, bb: int = 64, interpret: bool = True):
    """p/g: (n_q, Q); codes: int8 (n_q, Q); scales: f32 (n_q,);
    scalars: f32 (8,) = [lr, b1, b2, bc1, bc2, eps, wd, 0].
    Returns (new_p, new_m_codes, new_m_scales, new_v_codes, new_v_scales)."""
    n_q, q = p.shape
    assert n_q % bb == 0, (n_q, bb)
    grid = (n_q // bb,)
    blk2 = pl.BlockSpec((bb, q), lambda i: (i, 0))
    blk1 = pl.BlockSpec((bb,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8,), lambda i: (0,)),
                  blk2, blk2, blk2, blk1, blk2, blk1],
        out_specs=[blk2, blk2, blk1, blk2, blk1],
        out_shape=[
            jax.ShapeDtypeStruct((n_q, q), p.dtype),
            jax.ShapeDtypeStruct((n_q, q), jnp.int8),
            jax.ShapeDtypeStruct((n_q,), jnp.float32),
            jax.ShapeDtypeStruct((n_q, q), jnp.int8),
            jax.ShapeDtypeStruct((n_q,), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, p, g, m_codes, m_scales, v_codes, v_scales)
