"""Fused blockwise 8-bit Adam update kernel (paper §5.1 "8-bit SLTrain").

One pass over the parameter: dequantize both moments, Adam update, write
the new parameter AND requantize the moments — the f32 moments exist only
as VMEM transients, never in HBM. The XLA reference path
(``repro.optim.quant`` + ``optim.optimizers.adam8bit``) round-trips f32
moments through HBM; the fused kernel removes 8 bytes/param of HBM traffic
per step, which is the dominant memory term of the optimizer phase.

Layout: the flattened parameter is reshaped to (n_q, Q) quantization
blocks (Q = oc.q_block, default 256). Grid tiles BB quantization blocks per
kernel instance. Scalars arrive as one (10,) f32 operand broadcast to every
instance: [lr, b1, b2, omb1, omb2, bc1, bc2, eps, wd, 0]. ``omb1``/``omb2``
are the PRECOMPUTED (1 - beta) terms — deriving them in-kernel from the f32
betas loses ~half the bits of (1 - b2) ≈ 1e-3 and made the kernel drift
~1e-5 relative from the ``optim/quant.py`` reference (the ISSUE-4 audit).

``n_valid`` (a separate (1,) int32 operand — parameter counts exceed the
f32 24-bit integer range at 7B scale) masks the zero-padded tail lanes of
the last quantization block: padded m/v are pinned to exactly 0 so the
requantized state is BITWISE identical to the reference (which re-pads with
zeros every step), and a padded lane can never contaminate the last real
block's scale. The audit showed the old unmasked pads were *bounded* (the
v floor keeps them ≤ half a quantization step below the block max) but not
bit-identical — v pad codes round-tripped through the half-step floor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(s_ref, n_ref, p_ref, g_ref, mc_ref, ms_ref, vc_ref, vs_ref,
            po_ref, mco_ref, mso_ref, vco_ref, vso_ref, *, bb: int, q: int):
    lr, b1, b2, omb1, omb2, bc1, bc2, eps, wd, _ = [s_ref[i] for i in range(10)]
    # validity mask over this instance's (bb, q) flat lanes
    base = pl.program_id(0) * bb * q
    flat = base \
        + jax.lax.broadcasted_iota(jnp.int32, (bb, q), 0) * q \
        + jax.lax.broadcasted_iota(jnp.int32, (bb, q), 1)
    valid = flat < n_ref[0]
    g = jnp.where(valid, g_ref[...].astype(jnp.float32), 0.0)
    p = p_ref[...].astype(jnp.float32)
    # dequantize (symmetric signed m; shifted unsigned v). The v code is
    # floored at half a quantization step: a linear code zero-quantizes
    # small v within a block, and m/(sqrt(0)+eps) explodes the update
    # (bitsandbytes avoids this with a dynamic exponent code; the floor is
    # the linear-code equivalent — see test_adam8bit_converges_like_fp32).
    # Padded lanes are forced to exactly 0 (the floor must not resurrect
    # them — they carry no state and must quantize back to the same codes
    # the reference's zero re-pad produces).
    m = mc_ref[...].astype(jnp.float32) * ms_ref[...][:, None]
    v = jnp.maximum(vc_ref[...].astype(jnp.float32) + 128.0, 0.5) \
        * vs_ref[...][:, None]
    m = jnp.where(valid, m, 0.0)
    v = jnp.where(valid, v, 0.0)
    # Adam
    m = b1 * m + omb1 * g
    v = b2 * v + omb2 * g * g
    u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    u = u + wd * p
    po_ref[...] = (p - lr * u).astype(po_ref.dtype)
    # requantize
    ms = jnp.max(jnp.abs(m), axis=1) / 127.0
    mco_ref[...] = jnp.round(m / jnp.maximum(ms, 1e-12)[:, None]
                             ).astype(jnp.int8)
    mso_ref[...] = ms
    vs = jnp.max(v, axis=1) / 255.0
    vco_ref[...] = (jnp.round(v / jnp.maximum(vs, 1e-12)[:, None]) - 128.0
                    ).astype(jnp.int8)
    vso_ref[...] = vs


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def adam8bit_update(p, g, m_codes, m_scales, v_codes, v_scales, scalars,
                    n_valid, *, bb: int = 64, interpret: bool = True):
    """p/g: (n_q, Q); codes: int8 (n_q, Q); scales: f32 (n_q,);
    scalars: f32 (10,) = [lr, b1, b2, 1-b1, 1-b2, bc1, bc2, eps, wd, 0];
    n_valid: int32 (1,) — count of real (unpadded) elements.
    Returns (new_p, new_m_codes, new_m_scales, new_v_codes, new_v_scales)."""
    n_q, q = p.shape
    assert n_q % bb == 0, (n_q, bb)
    grid = (n_q // bb,)
    blk2 = pl.BlockSpec((bb, q), lambda i: (i, 0))
    blk1 = pl.BlockSpec((bb,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_kernel, bb=bb, q=q),
        grid=grid,
        in_specs=[pl.BlockSpec((10,), lambda i: (0,)),
                  pl.BlockSpec((1,), lambda i: (0,)),
                  blk2, blk2, blk2, blk1, blk2, blk1],
        out_specs=[blk2, blk2, blk1, blk2, blk1],
        out_shape=[
            jax.ShapeDtypeStruct((n_q, q), p.dtype),
            jax.ShapeDtypeStruct((n_q, q), jnp.int8),
            jax.ShapeDtypeStruct((n_q,), jnp.float32),
            jax.ShapeDtypeStruct((n_q, q), jnp.int8),
            jax.ShapeDtypeStruct((n_q,), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, n_valid, p, g, m_codes, m_scales, v_codes, v_scales)
