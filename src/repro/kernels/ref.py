"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def sl_matmul_ref(x, B, A, rows, cols, v, scale: float):
    """y = x @ (scale·B·A ⊕_(rows,cols) v), densified in f32."""
    W = (B.astype(jnp.float32) @ A.astype(jnp.float32)) * scale
    W = W.at[rows, cols].add(v.astype(jnp.float32), mode="drop",
                             unique_indices=True)
    return (x.astype(jnp.float32) @ W).astype(x.dtype)


def sddmm_ref(x, dy, rows, cols):
    """dv = (xᵀ·dy)[rows, cols] in f32."""
    G = x.astype(jnp.float32).T @ dy.astype(jnp.float32)
    return G[rows, cols]


def adam8bit_ref(p, g, m_codes, m_scales, v_codes, v_scales, scalars,
                 n_valid=None):
    """Blockwise 8-bit Adam step; shapes/scalar layout as in kernels.adam8bit
    ((10,) scalars with precomputed 1-beta slots). ``n_valid`` masks padded
    tail lanes exactly like the kernel (None = every lane is real)."""
    lr, b1, b2, omb1, omb2, bc1, bc2, eps, wd = [scalars[i] for i in range(9)]
    g = g.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    m = m_codes.astype(jnp.float32) * m_scales[:, None]
    # half-quant-step floor on v (see kernels/adam8bit.py)
    v = jnp.maximum(v_codes.astype(jnp.float32) + 128.0, 0.5) \
        * v_scales[:, None]
    if n_valid is not None:
        idx = jnp.arange(p.size, dtype=jnp.int32).reshape(p.shape)
        valid = idx < n_valid
        g = jnp.where(valid, g, 0.0)
        m = jnp.where(valid, m, 0.0)
        v = jnp.where(valid, v, 0.0)
    m = b1 * m + omb1 * g
    v = b2 * v + omb2 * g * g
    u = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * pf
    new_p = (pf - lr * u).astype(p.dtype)
    ms = jnp.max(jnp.abs(m), axis=1) / 127.0
    mc = jnp.round(m / jnp.maximum(ms, 1e-12)[:, None]).astype(jnp.int8)
    vs = jnp.max(v, axis=1) / 255.0
    vc = (jnp.round(v / jnp.maximum(vs, 1e-12)[:, None]) - 128.0
          ).astype(jnp.int8)
    return new_p, mc, ms, vc, vs


def sl_decode_ref(x, B, A, rows, cols, v, scale: float):
    """Oracle for the factored decode path — same densified math."""
    return sl_matmul_ref(x, B, A, rows, cols, v, scale)
