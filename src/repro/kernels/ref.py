"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def sl_matmul_ref(x, B, A, rows, cols, v, scale: float):
    """y = x @ (scale·B·A ⊕_(rows,cols) v), densified in f32."""
    W = (B.astype(jnp.float32) @ A.astype(jnp.float32)) * scale
    W = W.at[rows, cols].add(v.astype(jnp.float32), mode="drop",
                             unique_indices=True)
    return (x.astype(jnp.float32) @ W).astype(x.dtype)


def sddmm_ref(x, dy, rows, cols):
    """dv = (xᵀ·dy)[rows, cols] in f32."""
    G = x.astype(jnp.float32).T @ dy.astype(jnp.float32)
    return G[rows, cols]


def adam8bit_ref(p, g, m_codes, m_scales, v_codes, v_scales, scalars,
                 n_valid=None):
    """Blockwise 8-bit Adam step; shapes/scalar layout as in kernels.adam8bit
    ((10,) scalars with precomputed 1-beta slots). ``n_valid`` masks padded
    tail lanes exactly like the kernel (None = every lane is real)."""
    lr, b1, b2, omb1, omb2, bc1, bc2, eps, wd = [scalars[i] for i in range(9)]
    g = g.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    m = m_codes.astype(jnp.float32) * m_scales[:, None]
    # half-quant-step floor on v (see kernels/adam8bit.py)
    v = jnp.maximum(v_codes.astype(jnp.float32) + 128.0, 0.5) \
        * v_scales[:, None]
    if n_valid is not None:
        idx = jnp.arange(p.size, dtype=jnp.int32).reshape(p.shape)
        valid = idx < n_valid
        g = jnp.where(valid, g, 0.0)
        m = jnp.where(valid, m, 0.0)
        v = jnp.where(valid, v, 0.0)
    m = b1 * m + omb1 * g
    v = b2 * v + omb2 * g * g
    u = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * pf
    new_p = (pf - lr * u).astype(p.dtype)
    ms = jnp.max(jnp.abs(m), axis=1) / 127.0
    mc = jnp.round(m / jnp.maximum(ms, 1e-12)[:, None]).astype(jnp.int8)
    vs = jnp.max(v, axis=1) / 255.0
    vc = (jnp.round(v / jnp.maximum(vs, 1e-12)[:, None]) - 128.0
          ).astype(jnp.int8)
    return new_p, mc, ms, vc, vs


def sl_decode_ref(x, B, A, rows, cols, v, scale: float):
    """Oracle for the factored decode path — same densified math."""
    return sl_matmul_ref(x, B, A, rows, cols, v, scale)


def sl_quant_decode_ref(x, B, A, rows, cols, qv, ch_scales, scale: float):
    """Oracle for the quantized decode path (repro.quant): dequantize the
    int8 sparse codes against the per-output-channel scales, densify, and
    matmul in f32. ``qv`` int8 flat COO codes; ``ch_scales`` (d_out,) f32."""
    W = (B.astype(jnp.float32) @ A.astype(jnp.float32)) * scale
    v = qv.astype(jnp.float32) * ch_scales.astype(jnp.float32)[cols]
    W = W.at[rows, cols].add(v, mode="drop", unique_indices=True)
    return (x.astype(jnp.float32) @ W).astype(x.dtype)


def paged_attention_ref(q, k_pool, v_pool, block_table, positions, *,
                        scale: float, softcap: float = 0.0,
                        window: int = 0):
    """Oracle for kernels/paged_attention: densify the per-slot view and
    run masked softmax attention in f32. Same signature/semantics as the
    kernel — null blocks (table entry 0) and positions past the slot's
    query position are masked, masked probabilities are exactly 0, masked
    v rows are zeroed (garbage/NaN cannot ride a 0-weight product), and a
    slot with nothing valid (idle, parked on the null block) outputs 0.
    Doubles as the CPU fallback the tests pin interpret mode against.

    q: (n_slots, Hkv, group, hd); pools (n_blocks, block_len, Hkv, hd);
    block_table (n_slots, blocks_per_slot) int32; positions (n_slots,).
    """
    n_slots, n_kv, group, hd = q.shape
    block_len = k_pool.shape[1]
    k = jnp.take(k_pool, block_table, axis=0)        # (S, bps, bl, Hkv, hd)
    k = k.reshape(n_slots, -1, n_kv, hd).astype(jnp.float32)
    v = jnp.take(v_pool, block_table, axis=0)
    v = v.reshape(n_slots, -1, n_kv, hd).astype(jnp.float32)
    view_len = k.shape[1]

    kpos = jnp.arange(view_len, dtype=jnp.int32)
    valid = (kpos[None, :] <= positions[:, None]) & \
        jnp.repeat(block_table != 0, block_len, axis=1)
    if window > 0:
        valid &= (positions[:, None] - kpos[None, :]) < window

    s = jnp.einsum("shgd,slhd->shgl", q.astype(jnp.float32) * scale, k)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid[:, None, None, :], jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    v = jnp.where(valid[:, None, :, None], v.swapaxes(1, 2), 0.0)  # (S,H,l,d)
    o = jnp.einsum("shgl,shld->shgd", p, v) / jnp.where(l > 0, l, 1.0)
    return jnp.where(l > 0, o, 0.0).astype(q.dtype)


def paged_prefill_ref(q, k_pool, v_pool, block_table, offsets, *,
                      scale: float, softcap: float = 0.0, window: int = 0):
    """Oracle for kernels/paged_attention.paged_prefill: densify each
    slot's page view and run causal chunked-prefill attention in f32.
    Query i of slot s sits at absolute position ``offsets[s] + i`` and
    attends key positions ≤ its own (prior pages AND the chunk's earlier
    tokens, which the caller has already scattered into the pools). Null
    blocks are masked; fully-masked query rows (padding past the slot's
    suffix, idle slots) output exact zeros.

    q: (n_slots, sq, Hkv, group, hd); pools (n_blocks, block_len, Hkv, hd);
    block_table (n_slots, blocks_per_slot) int32; offsets (n_slots,).
    """
    n_slots, sq, n_kv, group, hd = q.shape
    block_len = k_pool.shape[1]
    k = jnp.take(k_pool, block_table, axis=0)        # (S, bps, bl, Hkv, hd)
    k = k.reshape(n_slots, -1, n_kv, hd).astype(jnp.float32)
    v = jnp.take(v_pool, block_table, axis=0)
    v = v.reshape(n_slots, -1, n_kv, hd).astype(jnp.float32)
    view_len = k.shape[1]

    kpos = jnp.arange(view_len, dtype=jnp.int32)
    qpos = offsets[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
    valid = (kpos[None, None, :] <= qpos[:, :, None]) & \
        jnp.repeat(block_table != 0, block_len, axis=1)[:, None, :]
    if window > 0:
        valid &= (qpos[:, :, None] - kpos[None, None, :]) < window

    s = jnp.einsum("sqhgd,slhd->sqhgl", q.astype(jnp.float32) * scale, k)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    mask = valid[:, :, None, None, :]                # (S, sq, 1, 1, l)
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    # zero v where NO query of the slot attends (null blocks / NaN guard)
    vmask = jnp.any(valid, axis=1)                   # (S, l)
    v = jnp.where(vmask[:, :, None, None, None], v[:, :, :, None, :],
                  0.0)                               # (S, l, H, 1, d)
    o = jnp.einsum("sqhgl,slhgd->sqhgd",
                   p, jnp.broadcast_to(v, v.shape[:3] + (group, hd)))
    o = o / jnp.where(l > 0, l, 1.0)
    return jnp.where(l > 0, o, 0.0).astype(q.dtype)
