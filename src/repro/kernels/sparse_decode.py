"""Sparse-only decode matmul kernel: y = x @ S  (DESIGN §3 beyond-paper).

Decode is weight-bound: the densify path reads 2 B/cell of W per step
(d_in·d_out·2 bytes). This kernel reads only the tile-CSR support —
v (4 B) + rows/cols (8 B) per NONZERO — i.e. 12·δ bytes/cell ≈ 0.36 B/cell
at δ=0.03, a 5.5× cut of the decode HBM term for the sparse component.
Combined with the factored low-rank part ((x·B)·A, plain XLA dots reading
(d_in+d_out)·r·2 bytes), the full SLTrain decode read shrinks by the
parameter-compression ratio — the serve_step "sparse" exec mode.

Body = the scatter-as-matmul of sl_matmul without the BA term: per (k, n)
tile build S_tile = P_rᵀ·diag(v)·P_c in VMEM (two one-hot MXU matmuls) and
immediately contract with x. S never exists in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, v_ref, r_ref, c_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bk = x_ref.shape[1]
    bn = o_ref.shape[1]
    rows = r_ref[0, 0, :]
    cols = c_ref[0, 0, :]
    v = v_ref[0, 0, :].astype(jnp.float32)
    e = rows.shape[0]
    pr = (rows[:, None] == jax.lax.broadcasted_iota(jnp.int32, (e, bk), 1))
    pc = (cols[:, None] == jax.lax.broadcasted_iota(jnp.int32, (e, bn), 1))
    s_tile = jax.lax.dot((pr.astype(jnp.float32) * v[:, None]).T,
                         pc.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    o_ref[...] += jax.lax.dot(x_ref[...].astype(jnp.float32), s_tile,
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def sparse_matmul(x, v_t, rows_t, cols_t, *, bm: int = 8, bk: int = 128,
                  bn: int = 128, interpret: bool = True):
    """y = x @ S for tile-CSR S; x (M, K) pre-padded to tile multiples.
    bm defaults small — decode batches are 1–128 rows."""
    m, k = x.shape
    nkt, nnt, e = rows_t.shape
    n = nnt * bn
    assert m % bm == 0 and k % bk == 0, (m, k)
    grid = (m // bm, nnt, nkt)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((1, 1, e), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, e), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, e), lambda i, j, kk: (kk, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, v_t, rows_t, cols_t)
    return out.astype(x.dtype)
