"""Sparse-only decode matmul kernel: y = x @ S  (DESIGN §3 beyond-paper).

Decode is weight-bound: the densify path reads 2 B/cell of W per step
(d_in·d_out·2 bytes). This kernel reads only the tile-CSR support —
v (4 B) + rows/cols (8 B) per NONZERO — i.e. 12·δ bytes/cell ≈ 0.36 B/cell
at δ=0.03, a 5.5× cut of the decode HBM term for the sparse component.
Combined with the factored low-rank part ((x·B)·A, plain XLA dots reading
(d_in+d_out)·r·2 bytes), the full SLTrain decode read shrinks by the
parameter-compression ratio — the serve_step "sparse" exec mode.

Body = the scatter-as-matmul of sl_matmul without the BA term: per (k, n)
tile build S_tile = P_rᵀ·diag(v)·P_c in VMEM (two one-hot MXU matmuls) and
immediately contract with x. S never exists in HBM.

The quantized sibling (:func:`quant_sparse_matmul`, the
``exec_mode="quant"`` serve path from repro.quant) consumes the int8
tile-CSR layout instead: qv (1 B) + int16 rows/cols (4 B) per nonzero
≈ 5·δ B/cell — a further 2.4× cut of the sparse decode term. Dequant
happens in VMEM: the tile is built from raw int8 codes and its columns
are rescaled against the per-output-channel f32 scale slice for that
column tile, so a code's scale is exactly scales[global_col] without any
per-entry gather (entries in column c of a tile land ONLY in s_tile
column c — a single row-vector multiply dequantizes the whole tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, v_ref, r_ref, c_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bk = x_ref.shape[1]
    bn = o_ref.shape[1]
    rows = r_ref[0, 0, :]
    cols = c_ref[0, 0, :]
    v = v_ref[0, 0, :].astype(jnp.float32)
    e = rows.shape[0]
    pr = (rows[:, None] == jax.lax.broadcasted_iota(jnp.int32, (e, bk), 1))
    pc = (cols[:, None] == jax.lax.broadcasted_iota(jnp.int32, (e, bn), 1))
    s_tile = jax.lax.dot((pr.astype(jnp.float32) * v[:, None]).T,
                         pc.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    o_ref[...] += jax.lax.dot(x_ref[...].astype(jnp.float32), s_tile,
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def sparse_matmul(x, v_t, rows_t, cols_t, *, bm: int = 8, bk: int = 128,
                  bn: int = 128, interpret: bool = True):
    """y = x @ S for tile-CSR S; x (M, K) pre-padded to tile multiples.
    bm defaults small — decode batches are 1–128 rows."""
    m, k = x.shape
    nkt, nnt, e = rows_t.shape
    n = nnt * bn
    assert m % bm == 0 and k % bk == 0, (m, k)
    grid = (m // bm, nnt, nkt)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((1, 1, e), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, e), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, e), lambda i, j, kk: (kk, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, v_t, rows_t, cols_t)
    return out.astype(x.dtype)


def _qkernel(x_ref, qv_ref, r_ref, c_ref, s_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bk = x_ref.shape[1]
    bn = o_ref.shape[1]
    rows = r_ref[0, 0, :].astype(jnp.int32)
    cols = c_ref[0, 0, :].astype(jnp.int32)
    qv = qv_ref[0, 0, :].astype(jnp.float32)
    e = rows.shape[0]
    pr = (rows[:, None] == jax.lax.broadcasted_iota(jnp.int32, (e, bk), 1))
    pc = (cols[:, None] == jax.lax.broadcasted_iota(jnp.int32, (e, bn), 1))
    # tile of raw int8 codes (padding slots carry qv == 0), then one
    # row-vector multiply dequantizes every column against its channel
    # scale — column c of s_tile holds exactly the entries with col == c
    s_tile = jax.lax.dot((pr.astype(jnp.float32) * qv[:, None]).T,
                         pc.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    s_tile = s_tile * s_ref[0, :][None, :]
    o_ref[...] += jax.lax.dot(x_ref[...].astype(jnp.float32), s_tile,
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def quant_sparse_matmul(x, qv_t, rows_q, cols_q, qscale, *, bm: int = 8,
                        bk: int = 128, bn: int = 128,
                        interpret: bool = True):
    """y = x @ dequant(S) for the int8 tile-CSR layout (repro.quant).

    qv_t int8 (nkt, nnt, E) codes baked in tile order; rows_q/cols_q
    int16 tile-local indices (< 128, the byte win over the bf16 path's
    int32 consts); qscale f32 (nnt, TILE) per-output-channel scales
    blocked by column tile. x (M, K) pre-padded to tile multiples;
    accumulation is f32 (one final rounding, like the bf16 kernel)."""
    m, k = x.shape
    nkt, nnt, e = rows_q.shape
    n = nnt * bn
    assert m % bm == 0 and k % bk == 0, (m, k)
    assert qscale.shape == (nnt, bn), (qscale.shape, nnt, bn)
    grid = (m // bm, nnt, nkt)
    out = pl.pallas_call(
        _qkernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((1, 1, e), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, e), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, e), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, qv_t, rows_q, cols_q, qscale)
    return out.astype(x.dtype)
