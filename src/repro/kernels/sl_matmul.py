"""Fused SLTrain matmul kernel: y = x @ (scale·B·A ⊕_I V)  (DESIGN §3.1).

TPU adaptation of the paper's scatter-add forward. The GPU reference
materializes W = BA ⊕ V in HBM and then runs a dense GEMM — two extra HBM
round-trips of d_in·d_out·2 bytes. Here each (k, n) grid cell *densifies
its own 128×128 tile in VMEM* and immediately feeds it to the MXU; the
dense W never exists in HBM.

Scatter-as-matmul (DESIGN §3.2): TPUs have no fast unstructured VMEM
scatter, so the per-tile scatter is expressed as

    W_tile += P_r^T · diag(v) · P_c,   P_r = onehot(rows, bk),
                                       P_c = onehot(cols, bn)

two small MXU matmuls — the sparse work also runs on the systolic array.

Support layout: ``support.tile_layout`` buckets the fixed support by
128×128 tile at init, padded to the per-tile max (uniform random support ⇒
tight concentration). Padding slots carry v = 0 so they contribute nothing.

Grid: (M/bm, N/bn, K/bk), k innermost; the f32 output block is revisited
across k and used as the accumulator (standard Pallas matmul pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, b_ref, a_ref, v_ref, r_ref, c_ref, o_ref, *,
            scale: float, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bk = b_ref.shape[0]
    bn = a_ref.shape[1]
    # low-rank tile: (bk, r) @ (r, bn) on the MXU, f32 accumulation
    w = jax.lax.dot(b_ref[...], a_ref[...],
                    preferred_element_type=jnp.float32) * scale
    # sparse tile via one-hot matmuls (scatter-as-matmul)
    rows = r_ref[0, 0, :]                                # (E,) local row ids
    cols = c_ref[0, 0, :]
    v = v_ref[0, 0, :].astype(jnp.float32)
    e = rows.shape[0]
    pr = (rows[:, None] == jax.lax.broadcasted_iota(jnp.int32, (e, bk), 1))
    pc = (cols[:, None] == jax.lax.broadcasted_iota(jnp.int32, (e, bn), 1))
    pr_v = pr.astype(jnp.float32) * v[:, None]           # diag(v) folded in
    w = w + jax.lax.dot(pr_v.T, pc.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    # consume the tile immediately: (bm, bk) @ (bk, bn)
    o_ref[...] += jax.lax.dot(x_ref[...], w.astype(x_ref.dtype),
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bk", "bn",
                                             "interpret"))
def sl_matmul(x, B, A, v_t, rows_t, cols_t, *, scale: float,
              bm: int = 128, bk: int = 128, bn: int = 128,
              interpret: bool = True):
    """x (M,K) @ (scale·B(K,r)·A(r,N) ⊕ V) → (M,N) in x.dtype.

    v_t/rows_t/cols_t: (K/bk, N/bn, E) tile-CSR arrays from
    ``ops.prepare_tiles`` (E = padded per-tile capacity, pad v = 0).
    Shapes must be pre-padded to tile multiples (ops.py handles this).
    """
    m, k = x.shape
    n = A.shape[1]
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n)
    assert rows_t.shape[:2] == (k // bk, n // bn), rows_t.shape
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, B.shape[1]), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((A.shape[0], bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, 1, v_t.shape[-1]), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, rows_t.shape[-1]), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, cols_t.shape[-1]), lambda i, j, kk: (kk, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, B, A, v_t, rows_t, cols_t)
    return out.astype(x.dtype)
