"""jit'd wrappers around the Pallas kernels: shape padding, tile-CSR
support preparation, and the custom-VJP SLTrain linear that fuses
``sl_matmul`` forward with the ``sddmm`` backward.

``interpret=True`` everywhere on CPU (this container); on TPU the same
calls lower to real Mosaic kernels (interpret=False).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import support as support_lib
from repro.kernels import adam8bit as adam8bit_kernel
from repro.kernels import sddmm as sddmm_kernel
from repro.kernels import sl_matmul as sl_kernel

INTERPRET = True  # flipped to False by the TPU launcher


# ---------------------------------------------------------------------------
# Tile-CSR support preparation (init-time, host numpy)
# ---------------------------------------------------------------------------

def _tile_index_arrays(rows: np.ndarray, cols: np.ndarray, d_in: int,
                       d_out: int, tile_r: int, tile_c: int,
                       pad: int | None):
    """Shared tile-CSR layout body: pad dims to tile multiples, bucket the
    support, and shape the index arrays. Returns numpy
    (rows_t, cols_t, perm), each (K/tile_r, N/tile_c, E) int32 — the ONE
    place the tile geometry is computed, so value-baking (prepare_tiles)
    and fused index consts (prepare_tile_consts) can never desync."""
    kp = ((d_in + tile_r - 1) // tile_r) * tile_r
    np_ = ((d_out + tile_c - 1) // tile_c) * tile_c
    perm, local, counts, pad = support_lib.tile_layout(
        rows, cols, kp, np_, tile_r, tile_c, pad=pad)
    nkt, nnt = kp // tile_r, np_ // tile_c
    rt = local[:, 0].reshape(nkt, nnt, pad).astype(np.int32)
    ct = local[:, 1].reshape(nkt, nnt, pad).astype(np.int32)
    return rt, ct, perm.reshape(nkt, nnt, pad)


def prepare_tiles(rows: np.ndarray, cols: np.ndarray, v: np.ndarray,
                  d_in: int, d_out: int, tile_r: int = support_lib.TILE,
                  tile_c: int = support_lib.TILE, pad: int | None = None,
                  ) -> Tuple[jnp.ndarray, jnp.ndarray,
                             jnp.ndarray, jnp.ndarray]:
    """COO support + values → 4-tuple (v_t, rows_t, cols_t, perm), each of
    shape (K/tile_r, N/tile_c, E): the layout both kernels consume plus the
    permutation back into COO order (perm == -1 on padding slots, which
    carry v = 0 at local (0, 0)). Dims are padded up to tile multiples.
    ``pad`` forces a deterministic per-tile capacity E (see
    ``support.tile_cap``); by default E is the realized per-tile max."""
    rt, ct, perm = _tile_index_arrays(rows, cols, d_in, d_out, tile_r,
                                      tile_c, pad)
    v_flat = np.asarray(v, dtype=np.float32).reshape(-1)
    vt = np.where(perm >= 0, v_flat[np.maximum(perm, 0)], 0.0
                  ).astype(np.float32)
    return (jnp.asarray(vt), jnp.asarray(rt), jnp.asarray(ct),
            jnp.asarray(perm))


def prepare_tile_consts(rows: np.ndarray, cols: np.ndarray, d_in: int,
                        d_out: int, *, pad: int,
                        tile_r: int = support_lib.TILE,
                        tile_c: int = support_lib.TILE) -> dict:
    """Tile-CSR *index* consts for ``exec_mode="fused"`` training:
    {rows_t, cols_t, perm}, each int32 (K/tile_r, N/tile_c, pad).

    Unlike :func:`prepare_tiles` this bakes NO values: the trainable ``v``
    stays flat in the param tree (optimizer state / checkpoints / the
    sparse decode path stay layout-independent) and is gathered into tile
    order through ``perm`` inside the jit'd forward (``sl_linear``). The
    capacity ``pad`` must be the deterministic ``support.tile_cap`` bound
    so abstract dry-run shapes match concrete init and per-layer consts
    stack; raises ``ValueError`` when the sampled support exceeds it
    (callers re-sample on host)."""
    rt, ct, perm = _tile_index_arrays(rows, cols, d_in, d_out, tile_r,
                                      tile_c, pad)
    return {"rows_t": jnp.asarray(rt), "cols_t": jnp.asarray(ct),
            "perm": jnp.asarray(perm)}


def _pad2(x, mult_r, mult_c):
    r = (-x.shape[0]) % mult_r
    c = (-x.shape[1]) % mult_c
    if r or c:
        x = jnp.pad(x, ((0, r), (0, c)))
    return x


# ---------------------------------------------------------------------------
# Forward / backward wrappers
# ---------------------------------------------------------------------------

def sl_matmul(x, B, A, v_t, rows_t, cols_t, scale: float, *,
              bm: int = 128, interpret: bool | None = None):
    """y = x @ (scale·B·A ⊕ V); arbitrary (unpadded) logical shapes."""
    interp = INTERPRET if interpret is None else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = A.shape[-1]
    xf = _pad2(x.reshape(-1, k), bm, 128)
    Bp = _pad2(B, 128, 1)
    Ap = _pad2(A, 1, 128)
    y = sl_kernel.sl_matmul(xf, Bp, Ap, v_t, rows_t, cols_t, scale=scale,
                            bm=bm, interpret=interp)
    m = int(np.prod(lead)) if lead else 1
    return y[:m, :n].reshape(*lead, n)


def sddmm(x, dy, rows_t, cols_t, *, bm: int = 128,
          interpret: bool | None = None):
    """dv tiles for support (rows_t, cols_t); x (..., K), dy (..., N).

    Output is f32: the kernel forms each G tile with
    ``preferred_element_type=f32`` and accumulates over the token grid in
    an f32 output block, so bf16 inputs never round dv through bf16 (same
    accumulation contract as the sparse-decode fix). Upstream often hands
    f32 cotangents against bf16 activations — align dy to x's dtype here
    (the MXU dot needs matching operand dtypes; accumulation stays f32)."""
    interp = INTERPRET if interpret is None else interpret
    k = x.shape[-1]
    n = dy.shape[-1]
    xf = _pad2(x.reshape(-1, k), bm, 128)
    dyf = _pad2(dy.reshape(-1, n).astype(x.dtype), bm, 128)
    return sddmm_kernel.sddmm(xf, dyf, rows_t, cols_t, bm=bm,
                              interpret=interp)


# ---------------------------------------------------------------------------
# Fused SLTrain linear: pallas forward + pallas backward, custom VJP
# ---------------------------------------------------------------------------

def _fused_grads_dist(x, B, A, v_t, rows_t, cols_t, scale, dy):
    """Distributed fused backward (the shard_map sibling of
    ``core.sltrain._grads_distributed``, for ``exec_mode="fused"``).

    Under pjit-auto with the tile consts sharded over model
    (dist/sharding: rows_t/cols_t/perm shard their nnt axis like A's
    d_out), the fused vjp's contractions would still make XLA assemble
    full-width operands. Tile-CSR is naturally shardable on the column-
    tile axis — a tile's indices are LOCAL to its 128×128 block, so a
    model shard's (nkt, nnt/TP, cap) const slice addresses exactly its
    own dy columns with no index arithmetic. The island runs the same
    eq.-(2) algebra as ``_fused_grads`` on local slices and psums only
    r- and tile-sized results:

      tokens over (pod, data); d_out / A / tile consts over model:
        dA  = psum_bt(scale · (x·B)ᵀ · dy_loc)      — stays model-sharded
        dB  = psum_bt+model(scale · xᵀ · (dy_loc·A_locᵀ))
        dv  = psum_bt(sddmm local tiles)            — stays model-sharded
        dx  = psum_model(sl_matmul(dy_loc, A_locᵀ, Bᵀ, local tilesᵀ))

    Returns (dx, dB, dA, dv_t f32) or None when the geometry doesn't
    shard (no mesh, TP=1, misaligned dims, down-projection) — callers
    fall back to the local path. Same try/except contract as the densify
    island: composition must degrade, never error."""
    from jax.sharding import PartitionSpec as P

    from repro.dist import compat, sharding as dist_sharding
    mesh = dist_sharding.ambient_mesh()
    if mesh is None or getattr(mesh, "empty", False) or x.ndim != 3 \
            or dy.ndim != 3:
        return None
    d_in = x.shape[-1]
    d_out = dy.shape[-1]
    if d_in > d_out:
        # island edge would gather the larger activation — the same wire
        # heuristic as the densify path (§Perf it.9)
        return None
    axes = mesh.axis_names
    bt = tuple(a for a in ("pod", "data") if a in axes)
    nb = int(np.prod([mesh.shape[a] for a in bt])) if bt else 1
    nm = mesh.shape.get("model", 1) if "model" in axes else 1
    nnt = v_t.shape[1]
    if (not bt or nm <= 1 or x.shape[0] % nb
            or d_out % (nm * 128) or nnt % nm):
        return None
    d_out_loc = d_out // nm
    f32 = jnp.float32

    def body(xs, dys, B_r, A_l, vt_l, rt_l, ct_l):
        xl = xs.reshape(-1, d_in)
        dyl = dys.reshape(-1, d_out_loc).astype(xl.dtype)
        xB = jnp.matmul(xl, B_r, preferred_element_type=f32)
        dA = jax.lax.psum(
            scale * jnp.matmul(xB.T, dyl.astype(f32)), bt)
        dyA = jnp.matmul(dyl, A_l.T, preferred_element_type=f32)
        dB = jax.lax.psum(
            scale * jnp.matmul(xl.astype(f32).T, dyA), bt + ("model",))
        dv = jax.lax.psum(sddmm(xl, dyl, rt_l, ct_l), bt)
        dx = sl_matmul(dyl, A_l.T, B_r.T, jnp.swapaxes(vt_l, 0, 1),
                       jnp.swapaxes(ct_l, 0, 1), jnp.swapaxes(rt_l, 0, 1),
                       scale)
        dx = jax.lax.psum(dx.astype(f32), "model")
        return dx, dB, dA, dv

    try:
        dx, dB, dA, dv_t = compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(bt, None, None), P(bt, None, "model"),
                      P(None, None), P(None, "model"),
                      P(None, "model", None), P(None, "model", None),
                      P(None, "model", None)),
            out_specs=(P(bt, None), P(None, None), P(None, "model"),
                       P(None, "model", None)),
            check_vma=False)(x, dy, B, A, v_t, rows_t, cols_t)
    except Exception:
        return None
    dx = dx.reshape(x.shape).astype(x.dtype)
    return dx, dB.astype(B.dtype), dA.astype(A.dtype), dv_t


def _fused_grads(x, B, A, v_t, rows_t, cols_t, scale, dy):
    """Shared backward math of the fused linear: (dx, dB, dA, dv_t f32).

    Factored grads via the (token-dim contracted) products — same algebra
    as core.sltrain; the d_in×d_out transient only ever exists per-tile
    inside the sddmm kernel. All chains accumulate in f32 (an xf@B whose
    RESULT is cast to f32 rounds the token contraction through bf16 first
    — the PR-1 sparse-decode bug class); dv_t stays the sddmm kernel's
    f32 accumulator output. When a TP mesh is ambient and the geometry
    divides, the work routes through :func:`_fused_grads_dist` instead
    (local slices + psum'd small results)."""
    out = _fused_grads_dist(x, B, A, v_t, rows_t, cols_t, scale, dy)
    if out is not None:
        return out
    k = x.shape[-1]
    n = dy.shape[-1]
    # backward activations in the model dtype (§Perf it.9), like the
    # densify path — also what lets the MXU dots pair matching dtypes
    dy = dy.astype(x.dtype)
    xf = x.reshape(-1, k)
    dyf = dy.reshape(-1, n)
    # bf16 operands with f32 accumulation (preferred_element_type) — the
    # products are exact in f32, so this equals an upcast matmul at native
    # MXU speed; the second-level dots carry the f32 intermediate
    f32 = jnp.float32
    xB = jnp.matmul(xf, B, preferred_element_type=f32)    # (M, r) f32
    dA = (scale * jnp.matmul(xB.T, dyf.astype(f32))).astype(A.dtype)
    dyA = jnp.matmul(dyf, A.T, preferred_element_type=f32)  # (M, r) f32
    dB = (scale * jnp.matmul(xf.astype(f32).T, dyA)).astype(B.dtype)
    dv_t = sddmm(xf, dyf, rows_t, cols_t)                 # f32 tiles
    # dx = dy @ W^T: reuse the fused kernel on the transposed factors. The
    # support transpose is (cols_t, rows_t) tiles transposed in the grid —
    # equivalently run sl_matmul with swapped tile axes.
    vt_T = jnp.swapaxes(v_t, 0, 1)
    rt_T = jnp.swapaxes(cols_t, 0, 1)
    ct_T = jnp.swapaxes(rows_t, 0, 1)
    dx = sl_matmul(dyf, A.T, B.T, vt_T, rt_T, ct_T, scale
                   ).reshape(x.shape).astype(x.dtype)
    return dx, dB, dA, dv_t


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def sl_linear_fused(x, B, A, v_t, rows_t, cols_t, scale):
    return sl_matmul(x, B, A, v_t, rows_t, cols_t, scale)


def _fused_fwd(x, B, A, v_t, rows_t, cols_t, scale):
    y = sl_matmul(x, B, A, v_t, rows_t, cols_t, scale)
    return y, (x, B, A, v_t, rows_t, cols_t)


def _fused_bwd(scale, res, dy):
    x, B, A, v_t, rows_t, cols_t = res
    dx, dB, dA, dv_t = _fused_grads(x, B, A, v_t, rows_t, cols_t, scale, dy)
    return dx, dB, dA, dv_t.astype(v_t.dtype), None, None


sl_linear_fused.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# Flat-v fused linear (exec_mode="fused" training path)
# ---------------------------------------------------------------------------

def _gather_tiles(v, perm):
    """Flat trainable v → f32 tile values through the layout permutation.
    Padding slots (perm == -1) contribute exactly 0 through the kernel."""
    vf = v.reshape(-1).astype(jnp.float32)
    safe = jnp.clip(perm, 0, vf.shape[0] - 1)
    return jnp.where(perm >= 0, vf[safe], 0.0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def sl_linear(x, B, A, v, rows_t, cols_t, perm, scale):
    """y = x @ (scale·B·A ⊕ V) with the trainable ``v`` in its FLAT layout
    (row-balanced (d_in, k) or COO (nnz,)) — the param-tree leaf the
    optimizer/checkpoints see. The tile gather (fwd) and scatter (bwd)
    happen inside the jit, so only the layout-independent flat v is ever
    state; tile order is a pure function of the int consts from
    ``prepare_tile_consts``."""
    return sl_matmul(x, B, A, _gather_tiles(v, perm), rows_t, cols_t, scale)


def _sl_linear_fwd(x, B, A, v, rows_t, cols_t, perm, scale):
    v_t = _gather_tiles(v, perm)
    y = sl_matmul(x, B, A, v_t, rows_t, cols_t, scale)
    # residuals stay factored-sized (Alg. 1): v_t is nnz+pad floats, never
    # the (d_in, d_out) dense W
    return y, (x, B, A, v, v_t, rows_t, cols_t, perm)


def _sl_linear_bwd(scale, res, dy):
    x, B, A, v, v_t, rows_t, cols_t, perm = res
    dx, dB, dA, dv_t = _fused_grads(x, B, A, v_t, rows_t, cols_t, scale, dy)
    # scatter the f32 tile grads back through perm onto the flat layout;
    # every valid perm entry appears exactly once (tile_layout invariant)
    # so the add is exact, padding rides the clipped index with a 0 value
    pf = perm.reshape(-1)
    flat = jnp.where(pf >= 0, dv_t.reshape(-1), 0.0)
    dv = jnp.zeros((v.size,), jnp.float32).at[
        jnp.clip(pf, 0, v.size - 1)].add(flat)
    return (dx, dB, dA, dv.reshape(v.shape).astype(v.dtype),
            None, None, None)


sl_linear.defvjp(_sl_linear_fwd, _sl_linear_bwd)


# ---------------------------------------------------------------------------
# 8-bit Adam wrapper (flat pytree leaf)
# ---------------------------------------------------------------------------

def adam8bit_update(p, g, m_codes, m_scales, v_codes, v_scales, *,
                    lr, b1, b2, bc1, bc2, eps, wd, q: int = 256,
                    omb1=None, omb2=None, interpret: bool | None = None):
    """One fused 8-bit Adam step on an arbitrary-shape leaf.

    ``omb1``/``omb2`` are the (1 - beta) terms; when the betas are plain
    python floats they default to the full-precision python subtraction,
    matching the ``optim/quant.py`` reference bitwise (an in-kernel f32
    ``1 - b2`` loses ~half the bits of the ~1e-3 difference — ISSUE-4
    audit)."""
    interp = INTERPRET if interpret is None else interpret
    shape = p.shape
    n = p.size
    pad = (-n) % q
    if omb1 is None:
        omb1 = 1.0 - b1
    if omb2 is None:
        omb2 = 1.0 - b2

    def blk(a):
        """Pad a logical-size leaf (p, g) up to whole quantization blocks.
        Codes/scales are already block-shaped and pass through reshape."""
        f = a.reshape(-1)
        if f.size == n and pad:
            f = jnp.pad(f, (0, pad))
        return f.reshape(-1, q)

    n_q = (n + pad) // q
    bb = 1
    for cand in (64, 32, 16, 8, 4, 2, 1):
        if n_q % cand == 0:
            bb = cand
            break
    scalars = jnp.array([lr, b1, b2, omb1, omb2, bc1, bc2, eps, wd, 0.0],
                        jnp.float32)
    n_valid = jnp.array([n], jnp.int32)
    new_p, mc, ms, vc, vs = adam8bit_kernel.adam8bit_update(
        blk(p), blk(g), blk(m_codes), m_scales.reshape(-1),
        blk(v_codes), v_scales.reshape(-1), scalars, n_valid,
        bb=bb, interpret=interp)
    return (new_p.reshape(-1)[:n].reshape(shape), mc, ms, vc, vs)


# ---------------------------------------------------------------------------
# Paged-attention decode (serve path: attend over KV block pools in place)
# ---------------------------------------------------------------------------

def paged_attention(q, k_pool, v_pool, block_table, positions, *,
                    scale: float, softcap: float = 0.0, window: int = 0,
                    interpret: bool | None = None):
    """Decode attention directly over the paged K/V pools (serve/kv.py) —
    the ``attn_kernel="paged"`` path of ``models/attention``.

    q: (n_slots, H, hd) — ONE query token per slot, already rope'd; pools
    (n_blocks, block_len, Hkv, hd); block_table (n_slots, blocks_per_slot)
    int32; positions (n_slots,) int32 per-slot query positions. Handles
    GQA by regrouping q to (n_slots, Hkv, H//Hkv, hd) so each kv head's
    block stream serves its whole query group. Returns (n_slots, H, hd)
    in q.dtype. Unlike the gather path this never materializes the
    (n_slots, view_len) per-slot view: HBM K/V traffic is the slots' live
    blocks, not n_slots × view_len.
    """
    from repro.kernels import paged_attention as pa_kernel
    interp = INTERPRET if interpret is None else interpret
    n_slots, n_heads, hd = q.shape
    n_kv = k_pool.shape[2]
    assert n_heads % n_kv == 0, (n_heads, n_kv)
    q4 = q.reshape(n_slots, n_kv, n_heads // n_kv, hd)
    out = pa_kernel.paged_attention(
        q4, k_pool, v_pool, block_table.astype(jnp.int32),
        positions.astype(jnp.int32), scale=scale, softcap=softcap,
        window=window, interpret=interp)
    return out.reshape(n_slots, n_heads, hd)


def paged_prefill_attention(q, k_pool, v_pool, block_table, offsets, *,
                            scale: float, softcap: float = 0.0,
                            window: int = 0,
                            interpret: bool | None = None):
    """Chunked-prefill attention over paged pools — the prefill sibling of
    :func:`paged_attention` for ``attn_kernel="paged"``.

    q: (n_slots, sq, H, hd) — each slot's SUFFIX chunk, rope'd at absolute
    positions offsets[s] + [0, sq); the chunk's own K/V must already be
    scattered into the pools (the kernel attends prior pages and the
    chunk through one causal block sweep — shared-prefix pages are read
    in place, never re-written). offsets: (n_slots,) int32 absolute
    position of each slot's first chunk token. Returns (n_slots, sq, H,
    hd) in q.dtype; padding rows / idle slots come back as exact zeros.
    """
    from repro.kernels import paged_attention as pa_kernel
    interp = INTERPRET if interpret is None else interpret
    n_slots, sq, n_heads, hd = q.shape
    n_kv = k_pool.shape[2]
    assert n_heads % n_kv == 0, (n_heads, n_kv)
    q5 = q.reshape(n_slots, sq, n_kv, n_heads // n_kv, hd)
    out = pa_kernel.paged_prefill(
        q5, k_pool, v_pool, block_table.astype(jnp.int32),
        offsets.astype(jnp.int32), scale=scale, softcap=softcap,
        window=window, interpret=interp)
    return out.reshape(n_slots, sq, n_heads, hd)


# ---------------------------------------------------------------------------
# Factored decode path (sparse-only kernel + small low-rank dots)
# ---------------------------------------------------------------------------

def sl_decode(x, B, A, v_t, rows_t, cols_t, scale: float, *,
              interpret: bool | None = None):
    """SLTrain decode matmul without densifying: (x·B)·A·scale + x·S via the
    sparse_decode kernel (DESIGN §3 beyond-paper). Reads factored bytes
    only — the decode HBM term drops by the compression ratio."""
    from repro.kernels import sparse_decode as sd_kernel
    interp = INTERPRET if interpret is None else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = A.shape[-1]
    xf = x.reshape(-1, k)
    m = xf.shape[0]
    bm = 8
    pad_m = (-m) % bm
    pad_k = (-k) % 128
    xp = jnp.pad(xf, ((0, pad_m), (0, pad_k)))
    # low-rank term in f32 (bf16 intermediate rounding drifts from the
    # densified path — same accumulation fix as core.sltrain sparse mode)
    y_lr = ((xf.astype(jnp.float32) @ B.astype(jnp.float32))
            @ A.astype(jnp.float32)) * scale
    y_sp = sd_kernel.sparse_matmul(xp, v_t, rows_t, cols_t, bm=bm,
                                   interpret=interp)[:m, :n]
    return (y_lr + y_sp.astype(jnp.float32)).astype(x.dtype).reshape(*lead, n)


def sl_quant_decode(x, B, A, qv_t, rows_q, cols_q, qscale, scale: float, *,
                    interpret: bool | None = None):
    """Quantized SLTrain decode matmul (``exec_mode="quant"``, repro.quant):
    (x·B)·A·scale in f32 + x·dequant(S) through the int8 tile-CSR kernel.
    B/A are the bf16 error-folded factors from quant.calibrate; the sparse
    term reads qv int8 + int16 local indices + the per-channel f32 scale
    vector — ~5·δ B/cell vs the bf16 tile-CSR's 12·δ."""
    from repro.kernels import sparse_decode as sd_kernel
    interp = INTERPRET if interpret is None else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = A.shape[-1]
    xf = x.reshape(-1, k)
    m = xf.shape[0]
    bm = 8
    xp = jnp.pad(xf, ((0, (-m) % bm), (0, (-k) % 128)))
    y_lr = ((xf.astype(jnp.float32) @ B.astype(jnp.float32))
            @ A.astype(jnp.float32)) * scale
    y_sp = sd_kernel.quant_sparse_matmul(xp, qv_t, rows_q, cols_q, qscale,
                                         bm=bm, interpret=interp)[:m, :n]
    return (y_lr + y_sp.astype(jnp.float32)).astype(x.dtype).reshape(*lead, n)
