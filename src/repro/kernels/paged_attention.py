"""Paged-attention kernels: attend directly over KV block pools, for
single-token decode AND chunked prefill.

The PR-2 paged serve path is correct but pays a per-layer gather: every
decode step materializes a dense ``(n_slots, view_len, Hkv, hd)`` per-slot
K/V view from the block pools before running dense attention over it, so
decode HBM traffic and scratch scale with the worst-case ``view_len``, not
with live tokens. This kernel is the vLLM-style fix: it reads K/V **blocks
in place** and computes flash-style online-softmax attention while
streaming them through VMEM — the gathered view never exists.

Layout and grid
---------------
Pools are the serve/kv.py layout ``(n_blocks, block_len, Hkv, hd)`` with
physical block 0 reserved as the null block; the per-slot block table
``(n_slots, blocks_per_slot)`` and position vector ``(n_slots,)`` ride the
**scalar-prefetch** channel (PrefetchScalarGridSpec), so each grid step's
BlockSpec ``index_map`` resolves the slot's next physical block id before
the body runs and Pallas double-buffers the block DMA like any other
pipelined input. Grid is ``(n_slots, Hkv, blocks_per_slot)`` with the
block dim innermost: one kernel instance owns one (slot, kv-head) pair and
revisits its output block across the block sweep, carrying the online
softmax state (m, l, acc) in VMEM scratch — the standard flash-decoding
accumulator pattern.

GQA is handled in-kernel: q arrives blocked as ``(slot, kv_head, group,
head_dim)`` so the whole query-head group of a kv head shares that head's
single K/V block fetch (the gather path re-reads the view once per q head
group via broadcasting instead).

Masking
-------
Both masks live inside the kernel, applied to scores AND to the value
rows (a masked probability is exactly 0, but ``0 · NaN = NaN`` — zeroing v
is what makes poisoned/garbage null-block rows unable to leak):

* position: key position ``j·block_len + t`` must be ≤ the slot's query
  position (decode writes the current token's K/V before attending, so
  "≤" includes it); a sliding window adds ``pos - kpos < window``;
* null block: a table entry of 0 (unallocated) masks the whole block.

A slot with nothing valid (idle rows parked on the null block) outputs
exact zeros instead of 0/0.

Chunked prefill (:func:`paged_prefill`)
---------------------------------------
The decode kernel's sibling for ``sq > 1``: a slot's prompt SUFFIX chunk
(its K/V already scattered into fresh pages) attends all prior pages in
place — including pages attached read-only from another request's
identical prompt prefix (serve/kv.py copy-on-write sharing) — plus
causally within the chunk. Same grid family ``(n_slots, Hkv,
blocks_per_slot)`` and scalar-prefetched block table, but the query block
is the whole chunk ``(sq, group, hd)`` flattened to ``(sq·group, hd)``
rows, the online-softmax state is carried per query ROW, and the
causal/window masks are per (query row, key): query i at absolute
position ``offset_s + i`` sees keys with ``kpos ≤ offset_s + i``. This is
what makes prefix reuse free: without it, prefilling the non-shared
suffix would first materialize a contiguous per-slot view (power-of-two
bucket padding over the FULL prompt); with it, prefill reads exactly the
resident pages and writes only the suffix.

The value rows of a block are zeroed where NO query row attends them
(null block, or wholly outside every query's window): a masked softmax
weight is exactly 0, but ``0 · NaN = NaN``, and all-invalid columns are
the only place garbage can be non-finite. Padding query rows (beyond a
slot's real suffix) normalize over an empty set and output exact zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # matches models/attention._attend's mask fill


def _kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, block_len: int, scale: float,
            softcap: float, window: int):
    s = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    phys = tbl_ref[s, j]                       # physical block id (0 = null)
    pos = pos_ref[s]                           # this slot's query position
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (group, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (block_len, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    kpos = j * block_len + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_len), 1)[0]                 # (block_len,)
    valid = (kpos <= pos) & (phys != 0)
    if window > 0:
        valid &= (pos - kpos) < window

    sc = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32)
    if softcap > 0:
        sc = jnp.tanh(sc / softcap) * softcap
    sc = jnp.where(valid[None, :], sc, NEG_INF)          # (group, block_len)
    v = jnp.where(valid[:, None], v, 0.0)

    m_prev = m_ref[...]                                  # (group, 1)
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # exp(NEG_INF - m) underflows to 0 only once a real score raised m;
    # while everything so far is masked, sc == m_new == NEG_INF and the
    # exp is 1 — the explicit where is what keeps masked weights at 0.
    p = jnp.where(valid[None, :], jnp.exp(sc - m_new), 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = jnp.where(l > 0, acc_ref[...] / safe,
                                0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "window",
                                             "interpret"))
def paged_attention(q, k_pool, v_pool, block_table, positions, *,
                    scale: float, softcap: float = 0.0, window: int = 0,
                    interpret: bool = True):
    """Decode attention over paged pools, no gathered view.

    q: (n_slots, Hkv, group, hd) — one query token per slot, already
    rope'd/normed, grouped by kv head; k_pool/v_pool: (n_blocks,
    block_len, Hkv, hd); block_table: (n_slots, blocks_per_slot) int32;
    positions: (n_slots,) int32 per-slot query positions. Returns
    (n_slots, Hkv, group, hd) in q.dtype (idle slots = exact zeros).
    """
    n_slots, n_kv, group, hd = q.shape
    _, block_len, pool_kv, pool_hd = k_pool.shape
    assert (pool_kv, pool_hd) == (n_kv, hd), (k_pool.shape, q.shape)
    bps = block_table.shape[1]
    assert block_table.shape == (n_slots, bps), block_table.shape
    assert positions.shape == (n_slots,), positions.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_slots, n_kv, bps),
        in_specs=[
            pl.BlockSpec((1, 1, group, hd),
                         lambda s, h, j, tbl, pos: (s, h, 0, 0)),
            # the paged read: the index_map resolves the slot's j-th
            # LOGICAL block to its physical pool block before the body
            # runs — this is the line that replaces kv.gather_view
            pl.BlockSpec((1, block_len, 1, hd),
                         lambda s, h, j, tbl, pos: (tbl[s, j], 0, h, 0)),
            pl.BlockSpec((1, block_len, 1, hd),
                         lambda s, h, j, tbl, pos: (tbl[s, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda s, h, j, tbl, pos: (s, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, hd), jnp.float32),   # acc
            pltpu.VMEM((group, 1), jnp.float32),    # running max m
            pltpu.VMEM((group, 1), jnp.float32),    # running sum l
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_len=block_len, scale=scale,
                          softcap=softcap, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_table, positions, q, k_pool, v_pool)


def _prefill_kernel(tbl_ref, off_ref, q_ref, k_ref, v_ref, o_ref,
                    acc_ref, m_ref, l_ref, *, block_len: int, sq: int,
                    group: int, scale: float, softcap: float, window: int):
    s = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    phys = tbl_ref[s, j]                       # physical block id (0 = null)
    off = off_ref[s]                           # first chunk query's position
    q = q_ref[0].astype(jnp.float32) * scale   # (sq, group, hd)
    hd = q.shape[-1]
    q2 = q.reshape(sq * group, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (block_len, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    kpos = j * block_len + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_len), 1)[0]                 # (block_len,)
    # query row r of the flattened (sq·group) block sits at absolute
    # position off + r // group (group-major flatten keeps a query's whole
    # GQA head group on adjacent rows, sharing this block fetch)
    qpos = off + jax.lax.broadcasted_iota(
        jnp.int32, (sq, group), 0).reshape(sq * group, 1)
    valid = (kpos[None, :] <= qpos) & (phys != 0)        # (sq·group, bl)
    if window > 0:
        valid &= (qpos - kpos[None, :]) < window

    sc = jax.lax.dot(q2, k.T, preferred_element_type=jnp.float32)
    if softcap > 0:
        sc = jnp.tanh(sc / softcap) * softcap
    sc = jnp.where(valid, sc, NEG_INF)
    # zero v rows no query attends (the only rows that may hold non-finite
    # garbage: the null block, or keys wholly outside every window) —
    # columns valid for SOME row carry real finite K/V, and their masked
    # rows contribute 0 · finite = 0
    v = jnp.where(jnp.any(valid, axis=0)[:, None], v, 0.0)

    m_prev = m_ref[...]                                  # (sq·group, 1)
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(sc - m_new), 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        out = jnp.where(l > 0, acc_ref[...] / safe, 0.0)
        o_ref[0, :, 0] = out.reshape(sq, group, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "window",
                                             "interpret"))
def paged_prefill(q, k_pool, v_pool, block_table, offsets, *,
                  scale: float, softcap: float = 0.0, window: int = 0,
                  interpret: bool = True):
    """Chunked-prefill attention over paged pools, no gathered view.

    q: (n_slots, sq, Hkv, group, hd) — each slot's suffix chunk, already
    rope'd/normed at absolute positions offsets[s] + [0, sq), grouped by
    kv head; k_pool/v_pool: (n_blocks, block_len, Hkv, hd) with the
    chunk's OWN K/V already scattered in (the kernel attends prior pages
    AND the chunk through the same block sweep, causally); block_table:
    (n_slots, blocks_per_slot) int32; offsets: (n_slots,) int32 absolute
    position of each slot's first chunk query (the shared-prefix length).
    Returns (n_slots, sq, Hkv, group, hd) in q.dtype; padding query rows
    and idle slots come back as exact zeros.
    """
    n_slots, sq, n_kv, group, hd = q.shape
    _, block_len, pool_kv, pool_hd = k_pool.shape
    assert (pool_kv, pool_hd) == (n_kv, hd), (k_pool.shape, q.shape)
    bps = block_table.shape[1]
    assert block_table.shape == (n_slots, bps), block_table.shape
    assert offsets.shape == (n_slots,), offsets.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_slots, n_kv, bps),
        in_specs=[
            pl.BlockSpec((1, sq, 1, group, hd),
                         lambda s, h, j, tbl, off: (s, 0, h, 0, 0)),
            pl.BlockSpec((1, block_len, 1, hd),
                         lambda s, h, j, tbl, off: (tbl[s, j], 0, h, 0)),
            pl.BlockSpec((1, block_len, 1, hd),
                         lambda s, h, j, tbl, off: (tbl[s, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, 1, group, hd),
                               lambda s, h, j, tbl, off: (s, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((sq * group, hd), jnp.float32),   # acc
            pltpu.VMEM((sq * group, 1), jnp.float32),    # running max m
            pltpu.VMEM((sq * group, 1), jnp.float32),    # running sum l
        ],
    )
    return pl.pallas_call(
        functools.partial(_prefill_kernel, block_len=block_len, sq=sq,
                          group=group, scale=scale, softcap=softcap,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_table, offsets, q, k_pool, v_pool)
