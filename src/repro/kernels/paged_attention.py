"""Paged-attention decode kernel: attend directly over KV block pools.

The PR-2 paged serve path is correct but pays a per-layer gather: every
decode step materializes a dense ``(n_slots, view_len, Hkv, hd)`` per-slot
K/V view from the block pools before running dense attention over it, so
decode HBM traffic and scratch scale with the worst-case ``view_len``, not
with live tokens. This kernel is the vLLM-style fix: it reads K/V **blocks
in place** and computes flash-style online-softmax attention while
streaming them through VMEM — the gathered view never exists.

Layout and grid
---------------
Pools are the serve/kv.py layout ``(n_blocks, block_len, Hkv, hd)`` with
physical block 0 reserved as the null block; the per-slot block table
``(n_slots, blocks_per_slot)`` and position vector ``(n_slots,)`` ride the
**scalar-prefetch** channel (PrefetchScalarGridSpec), so each grid step's
BlockSpec ``index_map`` resolves the slot's next physical block id before
the body runs and Pallas double-buffers the block DMA like any other
pipelined input. Grid is ``(n_slots, Hkv, blocks_per_slot)`` with the
block dim innermost: one kernel instance owns one (slot, kv-head) pair and
revisits its output block across the block sweep, carrying the online
softmax state (m, l, acc) in VMEM scratch — the standard flash-decoding
accumulator pattern.

GQA is handled in-kernel: q arrives blocked as ``(slot, kv_head, group,
head_dim)`` so the whole query-head group of a kv head shares that head's
single K/V block fetch (the gather path re-reads the view once per q head
group via broadcasting instead).

Masking
-------
Both masks live inside the kernel, applied to scores AND to the value
rows (a masked probability is exactly 0, but ``0 · NaN = NaN`` — zeroing v
is what makes poisoned/garbage null-block rows unable to leak):

* position: key position ``j·block_len + t`` must be ≤ the slot's query
  position (decode writes the current token's K/V before attending, so
  "≤" includes it); a sliding window adds ``pos - kpos < window``;
* null block: a table entry of 0 (unallocated) masks the whole block.

A slot with nothing valid (idle rows parked on the null block) outputs
exact zeros instead of 0/0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # matches models/attention._attend's mask fill


def _kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, block_len: int, scale: float,
            softcap: float, window: int):
    s = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    phys = tbl_ref[s, j]                       # physical block id (0 = null)
    pos = pos_ref[s]                           # this slot's query position
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (group, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (block_len, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    kpos = j * block_len + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_len), 1)[0]                 # (block_len,)
    valid = (kpos <= pos) & (phys != 0)
    if window > 0:
        valid &= (pos - kpos) < window

    sc = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32)
    if softcap > 0:
        sc = jnp.tanh(sc / softcap) * softcap
    sc = jnp.where(valid[None, :], sc, NEG_INF)          # (group, block_len)
    v = jnp.where(valid[:, None], v, 0.0)

    m_prev = m_ref[...]                                  # (group, 1)
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # exp(NEG_INF - m) underflows to 0 only once a real score raised m;
    # while everything so far is masked, sc == m_new == NEG_INF and the
    # exp is 1 — the explicit where is what keeps masked weights at 0.
    p = jnp.where(valid[None, :], jnp.exp(sc - m_new), 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = jnp.where(l > 0, acc_ref[...] / safe,
                                0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "window",
                                             "interpret"))
def paged_attention(q, k_pool, v_pool, block_table, positions, *,
                    scale: float, softcap: float = 0.0, window: int = 0,
                    interpret: bool = True):
    """Decode attention over paged pools, no gathered view.

    q: (n_slots, Hkv, group, hd) — one query token per slot, already
    rope'd/normed, grouped by kv head; k_pool/v_pool: (n_blocks,
    block_len, Hkv, hd); block_table: (n_slots, blocks_per_slot) int32;
    positions: (n_slots,) int32 per-slot query positions. Returns
    (n_slots, Hkv, group, hd) in q.dtype (idle slots = exact zeros).
    """
    n_slots, n_kv, group, hd = q.shape
    _, block_len, pool_kv, pool_hd = k_pool.shape
    assert (pool_kv, pool_hd) == (n_kv, hd), (k_pool.shape, q.shape)
    bps = block_table.shape[1]
    assert block_table.shape == (n_slots, bps), block_table.shape
    assert positions.shape == (n_slots,), positions.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_slots, n_kv, bps),
        in_specs=[
            pl.BlockSpec((1, 1, group, hd),
                         lambda s, h, j, tbl, pos: (s, h, 0, 0)),
            # the paged read: the index_map resolves the slot's j-th
            # LOGICAL block to its physical pool block before the body
            # runs — this is the line that replaces kv.gather_view
            pl.BlockSpec((1, block_len, 1, hd),
                         lambda s, h, j, tbl, pos: (tbl[s, j], 0, h, 0)),
            pl.BlockSpec((1, block_len, 1, hd),
                         lambda s, h, j, tbl, pos: (tbl[s, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda s, h, j, tbl, pos: (s, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, hd), jnp.float32),   # acc
            pltpu.VMEM((group, 1), jnp.float32),    # running max m
            pltpu.VMEM((group, 1), jnp.float32),    # running sum l
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_len=block_len, scale=scale,
                          softcap=softcap, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_table, positions, q, k_pool, v_pool)
