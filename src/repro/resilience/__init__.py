"""repro.resilience — deterministic fault injection + recovery policies.

Failure is a first-class, testable input: :mod:`repro.resilience.chaos`
turns a spec string like ``"kill@3,nonfinite@5,stall@4:8"`` into a
deterministic fault schedule keyed on (seed, step/tick) that the trainer
and serve engine replay exactly. The recovery side lives where the state
lives — non-finite skip/rollback in ``train.trainer``, checksummed
restore fallback in ``ckpt.checkpoint``, deadlines/shedding in
``serve.engine`` — and every recovery event lands on ``repro.obs``
counters (``resilience.*``, ``serve.rejected``,
``serve.deadline_exceeded``).
"""
from repro.resilience.chaos import ChaosEngine, ChaosKill, Fault

__all__ = ["ChaosEngine", "ChaosKill", "Fault"]
