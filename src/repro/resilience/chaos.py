"""Deterministic fault-injection harness (DESIGN §7: failure drills).

A :class:`ChaosEngine` parses a compact spec — ``kind@when[:arg]``,
comma-separated — into a schedule of faults that fire deterministically
on the trainer's step counter or the serve engine's tick clock:

=============  =====================================================
``kill@N``         raise :class:`ChaosKill` (``SystemExit`` with exit
                   code 43) before step N executes — a hard process
                   kill the relaunch must recover from
``nonfinite@N``    poison step N's loss with a NaN scale factor
                   (``batch["chaos_scale"]``) so non-finite values
                   propagate through the REAL vjp into the gradients
``ckpt_corrupt@N`` flip bytes in the newest checkpoint's
                   ``arrays.npz`` at step N (restore must detect the
                   damage and fall back to an intact step)
``data_corrupt@N`` overwrite batch tokens with out-of-range values at
                   step N (host-side validation must drop the batch)
``straggler@N:MS`` sleep MS milliseconds inside step N's timed window
                   (the step watchdog must flag it)
``stall@T:K``      serve: freeze one active slot for K engine ticks
                   starting at tick T (deadlines/drain must cope)
=============  =====================================================

Every fault fires AT MOST ONCE per engine instance (``@N`` means "the
first opportunity at or after N") — so steps re-executed after a
rollback are not re-poisoned, matching a transient hardware fault.
Randomized choices (which slot to stall) draw from a PRNG keyed on
(seed, fault time), never from global state, so a chaos run is exactly
reproducible. Injections are counted on the bound registry as
``resilience.faults_injected{kind=...}``.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

KINDS = ("kill", "nonfinite", "ckpt_corrupt", "data_corrupt", "straggler",
         "stall")


class ChaosKill(SystemExit):
    """Injected process kill. A ``SystemExit`` subclass so nothing up the
    stack accidentally swallows it with ``except Exception``; the exit
    code is distinct from the trainer's preemption exit (42) so harnesses
    can tell a drill from a real preemption."""

    EXIT_CODE = 43

    def __init__(self, step: int):
        super().__init__(self.EXIT_CODE)
        self.step = step


@dataclass(frozen=True)
class Fault:
    kind: str
    at: int                      # step (train) or tick (serve)
    arg: Optional[int] = None    # ms (straggler) / ticks (stall)


def corrupt_npz(path: str, *, seed: int = 0, n_bytes: int = 16) -> int:
    """Flip ``n_bytes`` bytes in the middle of ``path`` in place (XOR
    0xFF at a deterministic offset). Returns the offset. Used by the
    ``ckpt_corrupt`` fault and the fault-tolerance tests."""
    size = os.path.getsize(path)
    rng = np.random.default_rng(np.uint64(seed))
    # stay away from the zip end-of-central-directory record at the tail
    off = int(rng.integers(size // 4, max(size // 4 + 1, size // 2)))
    with open(path, "r+b") as f:
        f.seek(off)
        raw = f.read(n_bytes)
        f.seek(off)
        f.write(bytes(b ^ 0xFF for b in raw))
    return off


class ChaosEngine:
    """Holds the fault schedule plus fire-once state for one run."""

    def __init__(self, faults: List[Fault], *, seed: int = 0):
        for f in faults:
            if f.kind not in KINDS:
                raise ValueError(f"unknown fault kind {f.kind!r}: expected "
                                 f"one of {KINDS}")
        self.faults = list(faults)
        self.seed = seed
        self._fired: set = set()
        self._c_injected = None   # obs counter family, set by bind()

    @classmethod
    def parse(cls, spec: str, *, seed: int = 0) -> "ChaosEngine":
        """Parse ``"kind@when[:arg],..."`` (e.g. ``"kill@3"``,
        ``"nonfinite@5,straggler@4:50"``)."""
        faults = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            try:
                kind, rest = part.split("@", 1)
                arg = None
                if ":" in rest:
                    rest, a = rest.split(":", 1)
                    arg = int(a)
                faults.append(Fault(kind.strip(), int(rest), arg))
            except ValueError as e:
                raise ValueError(
                    f"bad chaos fault {part!r}: expected kind@when[:arg] "
                    f"with kind in {KINDS}") from e
        if not faults:
            raise ValueError(f"empty chaos spec {spec!r}")
        return cls(faults, seed=seed)

    def bind(self, obs) -> None:
        """Attach an ``obs.metrics.Registry`` so injections are counted
        (``resilience.faults_injected{kind=...}``)."""
        self._c_injected = obs.counter(
            "resilience.faults_injected",
            help="chaos faults injected, by kind")

    # -- internals ----------------------------------------------------------
    def _pending(self, kind: str, now: int) -> List[Fault]:
        return [f for f in self.faults
                if f.kind == kind and f not in self._fired and f.at <= now]

    def _fire(self, fault: Fault) -> None:
        self._fired.add(fault)
        if self._c_injected is not None:
            self._c_injected.labels(kind=fault.kind).inc()

    def _rng(self, at: int) -> np.random.Generator:
        return np.random.default_rng(np.uint64(self.seed * 1_000_003 + at))

    # -- train-side hooks ---------------------------------------------------
    @property
    def wants_poison(self) -> bool:
        """True when any ``nonfinite`` fault is scheduled — the trainer
        then carries ``batch["chaos_scale"]`` EVERY step (constant pytree
        structure, one compile) and only the value turns NaN."""
        return any(f.kind == "nonfinite" for f in self.faults)

    def train_hook(self, step: int, *, ckpt_dir: Optional[str] = None) -> None:
        """Top-of-loop faults: process kill and checkpoint corruption.
        ``ckpt_corrupt`` stays pending until a published checkpoint
        actually exists."""
        if ckpt_dir is not None:
            for f in self._pending("ckpt_corrupt", step):
                npz = _latest_ckpt_npz(ckpt_dir)
                if npz is None:
                    continue
                corrupt_npz(npz, seed=self.seed + f.at)
                self._fire(f)
        for f in self._pending("kill", step):
            self._fire(f)
            raise ChaosKill(step)

    def poison_scale(self, step: int) -> float:
        """NaN when a ``nonfinite`` fault fires at ``step``, else 1.0."""
        for f in self._pending("nonfinite", step):
            self._fire(f)
            return float("nan")
        return 1.0

    def corrupt_batch(self, step: int, batch: Dict[str, np.ndarray]
                      ) -> Dict[str, np.ndarray]:
        """Overwrite a stripe of tokens with out-of-range values — the
        trainer's host-side validation must reject the batch."""
        for f in self._pending("data_corrupt", step):
            self._fire(f)
            toks = np.array(batch["tokens"], copy=True)
            rng = self._rng(f.at)
            rows = rng.integers(0, toks.shape[0],
                                size=max(1, toks.shape[0] // 2))
            toks[rows, : max(1, toks.shape[1] // 4)] = -(7 + f.at)
            batch = dict(batch)
            batch["tokens"] = toks
        return batch

    def straggle(self, step: int) -> None:
        """Sleep inside the step's timed window (watchdog currency)."""
        for f in self._pending("straggler", step):
            self._fire(f)
            time.sleep((f.arg or 100) / 1e3)

    # -- serve-side hook ----------------------------------------------------
    def serve_hook(self, engine) -> None:
        """Per-tick hook (``ServeEngine(tick_hook=chaos.serve_hook)``):
        ``stall@T:K`` freezes one active slot — chosen by the keyed PRNG —
        for K ticks at the first tick ≥ T with any slot active."""
        for f in self._pending("stall", engine.clock):
            if engine.paged:
                slots = engine.sched.active_slots
            else:
                slots = [s for s in range(engine.n_slots)
                         if engine.slot_req[s] is not None]
            if not slots:
                continue      # stays pending until a slot is active
            slot = int(slots[int(self._rng(f.at).integers(len(slots)))])
            engine.stall_slot(slot, f.arg or 8)
            self._fire(f)


def _latest_ckpt_npz(ckpt_dir: str) -> Optional[str]:
    """Newest published checkpoint's arrays.npz (None when none yet)."""
    try:
        names = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return None
    steps = []
    for d in names:
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d[5:]))
            except ValueError:
                pass
    if not steps:
        return None
    path = os.path.join(ckpt_dir, f"step_{max(steps):08d}", "arrays.npz")
    return path if os.path.exists(path) else None
