"""repro.obs.trace — context-manager spans exporting Chrome trace-event
JSON (loadable in ``chrome://tracing`` / Perfetto).

Dependency-free: spans stamp a MONOTONIC wall clock
(``time.perf_counter_ns``) relative to the recorder's epoch and append
plain dicts in the Chrome trace-event format — complete events
(``ph="X"`` with ``ts``/``dur`` in microseconds) for spans, ``ph="i"``
instants, ``ph="M"`` metadata (thread names). ``export()`` writes the
``{"traceEvents": [...]}`` container.

Two timebases coexist in exported traces (the repo-wide contract — see
``repro.obs.__init__``):

* **wall spans** (:meth:`Trace.span`) measure real elapsed time on the
  monotonic clock — engine phases (admission, prefill dispatch, decode
  dispatch, block-until-ready) and trainer step phases (data, dispatch,
  sync). This is what an SLO means.
* **tick spans** (:meth:`Trace.event` with explicit ``ts``/``dur``) are
  laid out on a deterministic timeline by the caller — the serve engine
  plots per-request lifecycles (queued → prefill → decode) at 1 engine
  tick = :data:`TICK_US` microseconds, so span geometry reproduces tick
  TTFT exactly and the trace is byte-stable across runs. Tick spans carry
  their tick stamps in ``args`` too.

A disabled recorder (``Trace(enabled=False)``) turns ``span()`` into a
shared no-op context manager — hot loops pay one attribute check.

``jax.profiler`` hooks are OPTIONAL and gated: pass
``jax_profile_dir=...`` and :meth:`start`/:meth:`stop` bracket a
``jax.profiler`` trace session alongside the span recording (the import
happens inside ``start`` so this module stays jax-free otherwise).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

#: tick-timeline scale: 1 engine clock tick = 1000us in exported traces
TICK_US = 1000

_REQUIRED_KEYS = {"name", "ph", "ts", "pid", "tid"}


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_trace", "name", "cat", "tid", "args", "_t0")

    def __init__(self, trace: "Trace", name: str, cat: str, tid: Optional[int],
                 args: Optional[Dict[str, Any]]):
        self._trace = trace
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        tr = self._trace
        tr._append({
            "name": self.name, "cat": self.cat or "span", "ph": "X",
            "ts": (self._t0 - tr._epoch_ns) / 1e3,
            "dur": (t1 - self._t0) / 1e3,
            "pid": tr.pid,
            "tid": self.tid if self.tid is not None else _tid(),
            **({"args": self.args} if self.args else {}),
        })
        return False


def _tid() -> int:
    return threading.get_ident() & 0x7FFFFFFF


class Trace:
    """Span recorder. All mutation goes through ``_append`` (locked);
    events accumulate in memory until :meth:`export`."""

    def __init__(self, enabled: bool = True, *,
                 jax_profile_dir: Optional[str] = None):
        self.enabled = enabled
        self.pid = os.getpid()
        self._epoch_ns = time.perf_counter_ns()
        self._events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._jax_profile_dir = jax_profile_dir
        self._profiling = False

    # -- recording --------------------------------------------------------
    def span(self, name: str, cat: str = "", tid: Optional[int] = None,
             **args):
        """Context manager: one complete ("X") event on the wall clock."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, tid, args or None)

    def instant(self, name: str, cat: str = "", **args) -> None:
        if not self.enabled:
            return
        self._append({
            "name": name, "cat": cat or "instant", "ph": "i", "s": "t",
            "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3,
            "pid": self.pid, "tid": _tid(),
            **({"args": args} if args else {}),
        })

    def event(self, name: str, *, ts_us: float, dur_us: float,
              tid: int, cat: str = "",
              args: Optional[Dict[str, Any]] = None) -> None:
        """Append a complete event at an EXPLICIT position — the caller
        owns the timeline (the serve engine lays request lifecycles out on
        the tick clock at :data:`TICK_US` us/tick)."""
        if not self.enabled:
            return
        self._append({
            "name": name, "cat": cat or "span", "ph": "X",
            "ts": float(ts_us), "dur": float(dur_us),
            "pid": self.pid, "tid": int(tid),
            **({"args": args} if args else {}),
        })

    def thread_name(self, tid: int, label: str) -> None:
        """Metadata event: label a tid lane (e.g. one lane per request)."""
        if not self.enabled:
            return
        self._append({"name": "thread_name", "ph": "M", "ts": 0.0,
                      "pid": self.pid, "tid": int(tid),
                      "args": {"name": label}})

    def _append(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(ev)

    # -- jax.profiler hooks (flag-gated) ----------------------------------
    def start(self) -> None:
        """Begin an optional ``jax.profiler`` session when constructed
        with ``jax_profile_dir`` (no-op otherwise)."""
        if self._jax_profile_dir and not self._profiling:
            import jax
            jax.profiler.start_trace(self._jax_profile_dir)
            self._profiling = True

    def stop(self) -> None:
        if self._profiling:
            import jax
            jax.profiler.stop_trace()
            self._profiling = False

    # -- export -----------------------------------------------------------
    @property
    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def to_dict(self) -> Dict[str, Any]:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the Chrome trace container; returns the event count."""
        doc = self.to_dict()
        with open(path, "w") as f:
            json.dump(doc, f, separators=(",", ":"))
            f.write("\n")
        return len(doc["traceEvents"])


def validate(doc) -> int:
    """Validate a trace document (or bare event list) against the Chrome
    trace-event schema subset this module emits: every event carries
    name/ph/ts/pid/tid, ``ts``/``dur`` are finite non-negative numbers,
    complete ("X") events carry ``dur``, metadata ("M") events carry
    ``args``. Raises ValueError on the first violation; returns the event
    count (> 0 — an empty trace is a wiring bug, not a trace)."""
    events = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if not isinstance(events, list) or not events:
        raise ValueError("trace has no traceEvents list (or it is empty)")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object: {ev!r}")
        missing = _REQUIRED_KEYS - ev.keys()
        if missing:
            raise ValueError(f"event {i} ({ev.get('name')!r}): missing "
                             f"required keys {sorted(missing)}")
        for k in ("ts", "dur"):
            if k in ev:
                v = ev[k]
                if not isinstance(v, (int, float)) or v < 0 or \
                        v != v or v in (float("inf"),):
                    raise ValueError(f"event {i} ({ev['name']!r}): {k}={v!r}"
                                     " not a finite non-negative number")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"event {i} ({ev['name']!r}): complete event "
                             "without dur")
        if ev["ph"] == "M" and "args" not in ev:
            raise ValueError(f"event {i} ({ev['name']!r}): metadata event "
                             "without args")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i} ({ev['name']!r}): args not an "
                             "object")
    return len(events)


def validate_file(path: str) -> int:
    """JSON-load ``path`` and :func:`validate` it (CI smoke entry point)."""
    with open(path) as f:
        return validate(json.load(f))
