"""repro.obs — unified observability: metrics registry + span tracing.

Every measurement in the system flows through this package: the serve
engine's dispatch/traffic counters and TTFT histograms, the trainer's
loss/throughput/MFU gauges and step-phase spans, the per-layer sweep's
update timing, and the benchmark SLO rows (benchmarks/serve_bench.py
reads engine histograms instead of recomputing percentiles). It is
dependency-free (stdlib only) so obs can never be the reason a path
fails to import.

Instrument taxonomy (``repro.obs.metrics``)
-------------------------------------------
* **Counter** — monotone totals. Naming: ``<subsystem>.<noun>`` with
  labels for variants (``serve.dispatches{phase=prefill|decode}``,
  ``serve.prefill.tokens{kind=total|prefilled|shared}``). Counters are
  the currency of *how much work happened*.
* **Gauge** — last-written point-in-time values: *what is the system
  doing right now* (``train.loss``, ``train.tokens_per_sec``,
  ``train.mfu``, ``serve.sched.queue_depth``).
* **Histogram** — fixed-bucket latency/size distributions: *how is work
  distributed* (``serve.ttft_ticks``, ``serve.ttft_wall_ms``,
  ``train.step_ms``). No sample retention — p50/p99 come from bucket
  counts, exact for integer tick data on unit buckets.

The tick-vs-wall-clock contract
-------------------------------
The serving stack keeps TWO clocks, deliberately:

* **ticks** — the engine's dispatch clock (1 tick = 1 jit dispatch,
  prefill or decode). Ticks are DETERMINISTIC: the same workload yields
  the same tick TTFTs on any machine, so ticks are the testing and
  regression currency (``serve.ttft_ticks``, ``Request.arrival/
  t_first/t_done``, the SLO harness gates).
* **wall** — the monotonic host clock (``time.perf_counter``). Wall time
  is what an SLO actually promises a user, and the only clock that can
  see compile time, host scheduling, and real hardware speed
  (``serve.ttft_wall_ms``, ``Request.wall_arrival/wall_first/
  wall_done``).

Every latency is recorded in BOTH units; anything asserted in CI asserts
ticks, anything reported to a human shows both. Traces carry both too:
wall spans for engine/trainer phases, tick-timeline spans (1 tick =
``trace.TICK_US`` us) for per-request lifecycles — so a request's span
geometry in Perfetto reproduces its tick TTFT exactly.

Entry points: ``metrics.Registry`` / ``metrics.get_registry()`` and
``trace.Trace``; JSONL sink via ``Registry.write_jsonl``; Chrome-trace
export via ``Trace.export`` (validated by ``trace.validate``); optional
``jax.profiler`` sessions via ``Trace(jax_profile_dir=...)``.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricView,  # noqa: F401
                               Registry, get_registry, ms_buckets,
                               tick_buckets)
from repro.obs.trace import TICK_US, Trace, validate, validate_file  # noqa: F401
