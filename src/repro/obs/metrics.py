"""repro.obs.metrics — process-wide metrics registry (counters, gauges,
fixed-bucket histograms) with labeled instruments, ``snapshot()``, and a
JSONL sink.

Dependency-free by design (stdlib only — no jax import): instruments are
HOST-side accumulators. Every recorded value is coerced to a Python float
at the call site (``float(v)`` works on concrete jax arrays and forces
the host transfer right there); a jax *tracer* cannot be coerced, so
recording inside a jit trace fails loudly with a ``TypeError`` instead of
silently leaking the tracer into host state. That is the jit-safety
contract: record around jitted calls, never inside them (inside jit, use
``jax.experimental.io_callback`` to hop to host first — see
train/perlayer.py's layer timing).

Instrument taxonomy (see ``repro.obs.__init__`` for the full contract):

* :class:`Counter` — monotonically non-decreasing totals (dispatches,
  tokens, requests). ``inc(n)``; ``reset()`` zeroes (bench warmup).
* :class:`Gauge` — last-written point-in-time values (loss, tokens/sec,
  MFU, queue depth). ``set(v)``.
* :class:`Histogram` — fixed-bucket distributions (TTFT, step latency).
  Only per-bucket counts + sum are retained, never samples, so memory is
  O(buckets) regardless of traffic; p50/p99 come from the bucket counts
  (:meth:`Histogram.percentile`). With unit-width integer buckets
  (:func:`tick_buckets`) the percentiles of integer-valued data are
  EXACT (numpy-equivalent), because every sample in a bucket sits at the
  bucket bound.

Any instrument can carry labels: ``registry.counter("serve.dispatches")
.labels(phase="prefill")`` returns a child instrument; the parent is the
family (its value aggregates the children) and ``snapshot()`` flattens
children as ``name{k=v}``.

A module-level default registry (:func:`get_registry`) serves process-wide
use; subsystems that need isolated counters (a benchmark comparing four
engines) construct their own :class:`Registry`.
"""
from __future__ import annotations

import bisect
import json
import math
import threading
import time
from typing import Dict, Iterator, Mapping, Optional, Sequence, Tuple


def _as_float(v, what: str = "recorded value") -> float:
    """Coerce to a host float; a jax tracer (or anything float() rejects)
    raises TypeError — the no-tracer-leak guard."""
    try:
        return float(v)
    except Exception as e:  # ConcretizationTypeError, TypeError, ...
        raise TypeError(
            f"{what} of type {type(v).__name__} cannot be coerced to a "
            "host float — recording a jax tracer inside jit? obs "
            "instruments are host-side: record concrete values around "
            "jitted calls, or hop to host via jax.experimental.io_callback"
        ) from e


def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    return "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class _Instrument:
    """Shared label-family machinery. A parent instrument doubles as the
    family; ``labels(**kv)`` returns (get-or-create) the child keyed by
    the sorted label items."""

    kind = "instrument"

    def __init__(self, name: str, help: str = "",
                 label_items: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.help = help
        self.label_items = label_items
        self._children: Dict[Tuple[Tuple[str, str], ...], _Instrument] = {}
        self._lock = threading.Lock()

    def labels(self, **kv) -> "_Instrument":
        items = tuple(sorted((k, str(v)) for k, v in kv.items()))
        with self._lock:
            child = self._children.get(items)
            if child is None:
                child = self._make_child(items)
                self._children[items] = child
            return child

    def _make_child(self, items):
        raise NotImplementedError

    def reset(self) -> None:
        for c in self._children.values():
            c.reset()

    def _emit(self, out: Dict[str, dict]) -> None:
        """Flatten self + children into ``snapshot()`` rows."""
        if self._children:
            for items, c in sorted(self._children.items()):
                out[self.name + _fmt_labels(items)] = c._row()
            return
        out[self.name] = self._row()

    def _row(self) -> dict:
        raise NotImplementedError


class Counter(_Instrument):
    """Monotone total. ``value`` reads back as int when integral so
    counter views format/compare like the plain-int dicts they replace."""

    kind = "counter"

    def __init__(self, name, help="", label_items=()):
        super().__init__(name, help, label_items)
        self._v = 0.0

    def _make_child(self, items):
        return Counter(self.name, self.help, items)

    def inc(self, n=1) -> None:
        n = _as_float(n, f"counter {self.name} increment")
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        with self._lock:
            self._v += n

    @property
    def value(self):
        v = self._v + sum(c._v for c in self._children.values())
        return int(v) if float(v).is_integer() else v

    def reset(self) -> None:
        self._v = 0.0
        super().reset()

    def _row(self):
        return {"type": "counter", "value": self.value}


class Gauge(_Instrument):
    """Last-written value (None until first ``set``)."""

    kind = "gauge"

    def __init__(self, name, help="", label_items=()):
        super().__init__(name, help, label_items)
        self._v: Optional[float] = None

    def _make_child(self, items):
        return Gauge(self.name, self.help, items)

    def set(self, v) -> None:
        self._v = _as_float(v, f"gauge {self.name} value")

    @property
    def value(self) -> Optional[float]:
        return self._v

    def reset(self) -> None:
        self._v = None
        super().reset()

    def _row(self):
        return {"type": "gauge", "value": self._v}


#: default histogram bounds: exponential-ish latency grid in ms
DEFAULT_MS_BUCKETS = (0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0,
                      100.0, 200.0, 500.0, 1e3, 2e3, 5e3, 1e4, 3e4, 6e4,
                      3e5)


def ms_buckets() -> Tuple[float, ...]:
    """Wall-latency bucket bounds (ms), ~2-5x steps from 50us to 5min."""
    return DEFAULT_MS_BUCKETS


def tick_buckets(limit: int = 512) -> Tuple[int, ...]:
    """Unit-width integer bounds [0, limit): percentiles of integer data
    ≤ limit-1 (engine clock ticks) are exact — every sample in a bucket
    sits exactly at the bucket bound."""
    return tuple(range(limit))


class Histogram(_Instrument):
    """Fixed-bucket histogram: per-bucket counts + sum, no samples.

    ``bounds`` are ascending inclusive upper bounds; values above the last
    bound land in an implicit +inf overflow bucket. :meth:`percentile`
    reconstructs order statistics by placing each sample at its bucket's
    upper bound (overflow samples at the last finite bound) and applies
    numpy's linear interpolation between order statistics — exact for
    integer data on :func:`tick_buckets`, within one bucket width
    otherwise."""

    kind = "histogram"

    def __init__(self, name, buckets: Sequence[float], help="",
                 label_items=()):
        super().__init__(name, help, label_items)
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError(f"histogram {name}: needs >= 1 bucket bound")
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)  # +1 = overflow
        self._sum = 0.0

    def _make_child(self, items):
        return Histogram(self.name, self.bounds, self.help, items)

    def observe(self, v) -> None:
        v = _as_float(v, f"histogram {self.name} observation")
        with self._lock:
            self._counts[bisect.bisect_left(self.bounds, v)] += 1
            self._sum += v

    @property
    def count(self) -> int:
        return sum(self._counts) + sum(c.count for c in self._children.values())

    @property
    def sum(self) -> float:
        return self._sum + sum(c.sum for c in self._children.values())

    def _merged_counts(self):
        counts = list(self._counts)
        for c in self._children.values():
            for i, n in enumerate(c._merged_counts()):
                counts[i] += n
        return counts

    def _value_of_rank(self, k: int, counts, total: int) -> float:
        """Representative value of the k-th order statistic (0-based)."""
        k = min(max(k, 0), total - 1)
        cum = 0
        for i, n in enumerate(counts):
            cum += n
            if k < cum:
                return self.bounds[min(i, len(self.bounds) - 1)]
        return self.bounds[-1]

    def percentile(self, q: float) -> float:
        """q in [0, 100]; NaN on an empty histogram."""
        counts = self._merged_counts()
        total = sum(counts)
        if total == 0:
            return math.nan
        rank = (total - 1) * (q / 100.0)
        lo, hi = math.floor(rank), math.ceil(rank)
        v_lo = self._value_of_rank(lo, counts, total)
        v_hi = self._value_of_rank(hi, counts, total)
        return v_lo + (rank - lo) * (v_hi - v_lo)

    def reset(self) -> None:
        self._counts = [0] * (len(self.bounds) + 1)
        self._sum = 0.0
        super().reset()

    def _row(self):
        counts = self._counts
        buckets = [[self.bounds[i], c] for i, c in enumerate(counts[:-1])
                   if c]
        if counts[-1]:
            buckets.append(["+Inf", counts[-1]])
        total = sum(counts)
        row = {"type": "histogram", "count": total,
               "sum": round(self._sum, 6), "buckets": buckets}
        if total:
            row["p50"] = self.percentile(50)
            row["p99"] = self.percentile(99)
        return row


class MetricView(Mapping):
    """Read-only dict-shaped view over live instruments — the
    backward-compat shim for code that read the serve engine's counter
    dicts (``eng.dispatches["prefill"]``, ``dict(eng.kv_traffic)``).
    Reads always reflect the live registry; writes are impossible (reset
    through ``Registry.reset()`` / ``ServeEngine.reset_metrics()``)."""

    def __init__(self, instruments: Dict[str, _Instrument]):
        self._m = dict(instruments)

    def __getitem__(self, k):
        return self._m[k].value

    def __iter__(self) -> Iterator[str]:
        return iter(self._m)

    def __len__(self) -> int:
        return len(self._m)

    def __repr__(self) -> str:
        return f"MetricView({dict(self)!r})"


class Registry:
    """Name → instrument store. ``counter``/``gauge``/``histogram`` are
    get-or-create (re-registration with a conflicting type or bucket
    layout raises); ``snapshot()`` returns a plain-JSON dict and
    ``write_jsonl`` appends one snapshot line to a file."""

    def __init__(self):
        self._instruments: Dict[str, _Instrument] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, **kw) -> _Instrument:
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, **kw)
                self._instruments[name] = inst
                return inst
        if not isinstance(inst, cls):
            raise TypeError(f"instrument {name!r} already registered as "
                            f"{inst.kind}, not {cls.kind}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None,
                  help: str = "") -> Histogram:
        h = self._get(name, Histogram,
                      buckets=buckets if buckets is not None
                      else DEFAULT_MS_BUCKETS, help=help)
        if buckets is not None and \
                h.bounds != tuple(sorted(float(b) for b in buckets)):
            raise ValueError(f"histogram {name!r} already registered with "
                             f"different buckets")
        return h

    def get(self, name: str) -> Optional[_Instrument]:
        return self._instruments.get(name)

    def snapshot(self) -> Dict[str, dict]:
        out: Dict[str, dict] = {}
        with self._lock:
            insts = list(self._instruments.values())
        for inst in insts:
            inst._emit(out)
        return out

    def reset(self) -> None:
        """Zero every instrument (bench warmup / between measurements).
        Instrument objects stay registered — cached handles stay valid."""
        with self._lock:
            insts = list(self._instruments.values())
        for inst in insts:
            inst.reset()

    def to_prometheus_text(self) -> str:
        """Render the registry in the Prometheus text exposition format
        (version 0.0.4) — what a ``/metrics`` endpoint would serve.

        Per instrument family: ``# HELP`` / ``# TYPE`` header, then one
        sample per child (or the parent itself when unlabeled). Metric
        names are sanitized (``[^a-zA-Z0-9_:]`` → ``_``; a leading digit
        gets a ``_`` prefix), label values escape backslash, quote, and
        newline per the spec, and labels render in sorted-key order
        (``label_items`` is already sorted at creation). Histograms emit
        cumulative ``_bucket{le=...}`` series ending at ``le="+Inf"``
        plus ``_sum`` and ``_count``; unset gauges are skipped."""
        def san(name: str) -> str:
            s = "".join(ch if (ch.isascii() and (ch.isalnum() or ch in "_:"))
                        else "_" for ch in name)
            return "_" + s if s[:1].isdigit() else s

        def esc_label(v: str) -> str:
            return (v.replace("\\", "\\\\").replace('"', '\\"')
                    .replace("\n", "\\n"))

        def esc_help(v: str) -> str:
            return v.replace("\\", "\\\\").replace("\n", "\\n")

        def labelstr(items, extra=()) -> str:
            parts = [f'{san(k)}="{esc_label(str(v))}"'
                     for k, v in (*items, *extra)]
            return "{" + ",".join(parts) + "}" if parts else ""

        def num(v) -> str:
            f = float(v)
            if f != f:
                return "NaN"
            if f == math.inf:
                return "+Inf"
            if f == -math.inf:
                return "-Inf"
            return repr(int(f)) if f.is_integer() else repr(f)

        lines = []
        with self._lock:
            insts = sorted(self._instruments.items())
        for _, inst in insts:
            name = san(inst.name)
            if inst.help:
                lines.append(f"# HELP {name} {esc_help(inst.help)}")
            lines.append(f"# TYPE {name} {inst.kind}")
            children = ([inst._children[k] for k in sorted(inst._children)]
                        if inst._children else [inst])
            for ch in children:
                ls = ch.label_items
                if isinstance(ch, Counter):
                    lines.append(f"{name}{labelstr(ls)} {num(ch._v)}")
                elif isinstance(ch, Gauge):
                    if ch._v is not None:
                        lines.append(f"{name}{labelstr(ls)} {num(ch._v)}")
                elif isinstance(ch, Histogram):
                    cum = 0
                    for bound, n in zip(ch.bounds, ch._counts):
                        cum += n
                        lines.append(f"{name}_bucket"
                                     f"{labelstr(ls, (('le', num(bound)),))}"
                                     f" {cum}")
                    cum += ch._counts[-1]
                    lines.append(f"{name}_bucket"
                                 f"{labelstr(ls, (('le', '+Inf'),))} {cum}")
                    lines.append(f"{name}_sum{labelstr(ls)} {num(ch._sum)}")
                    lines.append(f"{name}_count{labelstr(ls)} {cum}")
        return "\n".join(lines) + ("\n" if lines else "")

    def write_jsonl(self, path: str, extra: Optional[dict] = None) -> None:
        """Append one ``{"ts": unix_s, ...extra, "metrics": snapshot}``
        line. One line per call — the caller owns the cadence (the trainer
        writes one per log interval; the serve launcher one per run)."""
        rec = {"ts": round(time.time(), 3)}
        if extra:
            rec.update(extra)
        rec["metrics"] = self.snapshot()
        with open(path, "a") as f:
            f.write(json.dumps(rec, separators=(",", ":"),
                               sort_keys=True) + "\n")


_REGISTRY = Registry()


def get_registry() -> Registry:
    """The process-wide default registry."""
    return _REGISTRY
